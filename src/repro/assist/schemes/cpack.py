"""C-Pack compression (paper 5.1.4), with the paper's exact simplifications.

Paper adaptations we reproduce:
* encodings reduced to: zero value, full dictionary match, partial match
  (only last byte mismatches), zero-extend (only last byte nonzero),
  uncompressed-line fallback;
* dictionary limited to 4 values -> FIXED compressed word size, so all words
  in the line compress/decompress in parallel;
* dictionary entries placed right after the metadata at the head of the line;
* dictionary built serially from the front of the line: each word becomes an
  entry if no existing entry covers it (paper Alg. 6) -- realized here as a
  `lax.scan` over word positions, vectorized across blocks (the per-lane
  predicate + global-AND structure of the paper maps to masked vector ops);
* if >4 entries would be needed, the line is left uncompressed (paper: "the
  cache line is left decompressed", a simplicity-vs-ratio trade).

Word size: 4 bytes.  Fixed layout per compressible block of W words:
  [dict: 4 x 4 B] [codes: 4 bits x W] [payload: 1 B x W]
Codes: 0 zero | 1..4 full match d0..d3 | 5..8 partial match d0..d3 | 9 zext.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.assist import bytesops as bo

WORD_BYTES = 4
NDICT = 4

CODE_ZERO = 0
CODE_FULL0 = 1   # ..4
CODE_PART0 = 5   # ..8
CODE_ZEXT = 9


def compressed_block_bytes(block_bytes: int) -> int:
    W = block_bytes // WORD_BYTES
    return NDICT * WORD_BYTES + W // 2 + W  # dict + nibble codes + payload


@partial(jax.tree_util.register_dataclass,
         data_fields=("ok", "dict_", "codes", "payload", "raw"),
         meta_fields=("shape", "dtype_name", "block_bytes", "pad"))
@dataclasses.dataclass(frozen=True)
class CPacked:
    """Fixed-rate C-Pack. ``ok[i]`` selects compressed vs raw block ``i``.

    Because the word size is fixed (paper's point), the compressed form has a
    static layout; ``raw`` keeps the uncompressible blocks (fallback), and
    accounting in :meth:`compressed_bytes` charges each block its true cost.
    """
    ok: jax.Array        # bool[nblocks]
    dict_: jax.Array     # uint32[nblocks, 4]
    codes: jax.Array     # uint8[nblocks, W/2]  (nibble-packed)
    payload: jax.Array   # uint8[nblocks, W]
    raw: jax.Array       # uint8[nblocks, B]  (zeros where ok)
    shape: tuple
    dtype_name: str
    block_bytes: int
    pad: int

    @property
    def nblocks(self):
        return self.ok.shape[0]

    def compressed_bytes(self) -> int:
        # sync-ok: cold-pack size accounting reads the feasibility count
        nc = int(np.asarray(jnp.sum(self.ok)))
        n = self.nblocks
        cb = compressed_block_bytes(self.block_bytes)
        return n + nc * cb + (n - nc) * self.block_bytes  # +1 B/blk metadata

    def original_bytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_name).itemsize

    def ratio(self) -> float:
        return self.original_bytes() / max(self.compressed_bytes(), 1)


def _covers(w: jax.Array, entry: jax.Array) -> jax.Array:
    """Is word ``w`` covered by dictionary entry (full or partial match)?"""
    full = w == entry
    partial = (w >> jnp.uint32(8)) == (entry >> jnp.uint32(8))
    return full | partial


def _self_covered(w: jax.Array) -> jax.Array:
    """zero or zero-extend words never consume a dictionary slot."""
    return (w == 0) | ((w >> jnp.uint32(8)) == 0)


def build_dictionary(w32: jax.Array):
    """w32: uint32[nb, W] -> (dict uint32[nb, 4], n_entries int32[nb],
    covered bool[nb, W]).  Serial front-to-back scan (paper Alg. 6)."""
    nb, W = w32.shape

    def step(carry, wi):
        dict_, count = carry               # [nb,4] uint32, [nb] int32
        covered = _self_covered(wi)
        for k in range(NDICT):
            covered = covered | _covers(wi, dict_[:, k]) & (count > k)
        need = (~covered) & (count < NDICT)
        # insert wi at position `count` where needed
        onehot = (jnp.arange(NDICT)[None, :] == count[:, None]) & need[:, None]
        dict_ = jnp.where(onehot, wi[:, None], dict_)
        count = count + need.astype(jnp.int32)
        return (dict_, count), None

    init = (jnp.zeros((nb, NDICT), jnp.uint32), jnp.zeros((nb,), jnp.int32))
    (dict_, count), _ = jax.lax.scan(step, init, w32.T)
    return dict_, count


def _assign_codes(w32: jax.Array, dict_: jax.Array, count: jax.Array):
    """codes uint8[nb, W], payload uint8[nb, W], ok bool[nb]."""
    nb, W = w32.shape
    codes = jnp.full((nb, W), 255, jnp.uint8)
    payload = jnp.zeros((nb, W), jnp.uint8)
    valid = count[:, None] > jnp.arange(NDICT)[None, :]      # [nb, 4]
    # priority: zero > full > zext > partial (cheapest information first)
    # partial (fill first so higher-priority assignments overwrite)
    for k in reversed(range(NDICT)):
        hit = ((w32 >> 8) == (dict_[:, k:k + 1] >> 8)) & valid[:, k:k + 1]
        codes = jnp.where(hit, jnp.uint8(CODE_PART0 + k), codes)
        payload = jnp.where(hit, (w32 & 0xFF).astype(jnp.uint8), payload)
    zext = (w32 >> 8) == 0
    codes = jnp.where(zext, jnp.uint8(CODE_ZEXT), codes)
    payload = jnp.where(zext, (w32 & 0xFF).astype(jnp.uint8), payload)
    for k in reversed(range(NDICT)):
        hit = (w32 == dict_[:, k:k + 1]) & valid[:, k:k + 1]
        codes = jnp.where(hit, jnp.uint8(CODE_FULL0 + k), codes)
        payload = jnp.where(hit, jnp.uint8(0), payload)
    zero = w32 == 0
    codes = jnp.where(zero, jnp.uint8(CODE_ZERO), codes)
    payload = jnp.where(zero, jnp.uint8(0), payload)
    ok = jnp.all(codes != 255, axis=-1)  # paper's global predicate AND
    codes = jnp.where(ok[:, None], codes, 0)
    return codes, payload, ok


def _pack_nibbles(codes: jax.Array) -> jax.Array:
    lo = codes[..., 0::2].astype(jnp.uint32)
    hi = codes[..., 1::2].astype(jnp.uint32)
    return (lo | (hi << 4)).astype(jnp.uint8)


def _unpack_nibbles(nib: jax.Array, W: int) -> jax.Array:
    n = nib.astype(jnp.uint32)
    out = jnp.stack([n & 0xF, (n >> 4) & 0xF], axis=-1)
    return out.reshape(*nib.shape[:-1], W).astype(jnp.uint8)


def compress(x: jax.Array, block_bytes: int = bo.DEFAULT_BLOCK_BYTES) -> CPacked:
    """Fixed-rate C-Pack compression (jit-friendly end to end)."""
    blocks, pad = bo.pad_to_blocks(bo.to_bytes(x), block_bytes)
    w32 = bo.words_from_block(blocks, WORD_BYTES)
    dict_, count = build_dictionary(w32)
    codes, payload, ok = _assign_codes(w32, dict_, count)
    raw = jnp.where(ok[:, None], jnp.uint8(0), blocks)
    return CPacked(ok=ok, dict_=dict_, codes=_pack_nibbles(codes),
                   payload=payload, raw=raw, shape=tuple(x.shape),
                   dtype_name=str(x.dtype), block_bytes=block_bytes, pad=pad)


def decompress(c: CPacked) -> jax.Array:
    """Parallel decode (paper Alg. 5): dictionary loads with lane masks."""
    B = c.block_bytes
    W = B // WORD_BYTES
    codes = _unpack_nibbles(c.codes, W).astype(jnp.int32)    # [nb, W]
    pay = c.payload.astype(jnp.uint32)
    # gather dictionary value per word
    didx_full = jnp.clip(codes - CODE_FULL0, 0, NDICT - 1)
    didx_part = jnp.clip(codes - CODE_PART0, 0, NDICT - 1)
    dfull = jnp.take_along_axis(c.dict_, didx_full, axis=-1)
    dpart = jnp.take_along_axis(c.dict_, didx_part, axis=-1)
    w = jnp.zeros(codes.shape, jnp.uint32)
    w = jnp.where((codes >= CODE_FULL0) & (codes < CODE_FULL0 + NDICT), dfull, w)
    part = (dpart & jnp.uint32(0xFFFFFF00)) | pay
    w = jnp.where((codes >= CODE_PART0) & (codes < CODE_PART0 + NDICT), part, w)
    w = jnp.where(codes == CODE_ZEXT, pay, w)
    dec = bo.block_from_words(w, WORD_BYTES, B)
    blocks = jnp.where(c.ok[:, None], dec, c.raw)
    flat = blocks.reshape(-1)
    n = int(np.prod(c.shape)) * jnp.dtype(c.dtype_name).itemsize
    return bo.from_bytes(flat[:n], c.dtype_name, c.shape)

"""BestOfAll scheme selection (paper Fig. 12/13 and 7.3).

The paper's CABA-BestOfAll picks the best algorithm per cache line; it also
notes a realistic selector must weigh ratio AGAINST decompression cost
("a mechanism that selects the best compression algorithm based on both
compression ratio and the relative cost of compression/decompression is
desirable").  We implement exactly that, at tensor-site granularity (the
trigger granularity on TPU, DESIGN.md 2.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax.numpy as jnp

from repro.assist.schemes import bdi, fpc, cpack, planes, quant

# decompression cost in VPU ops per uncompressed byte (napkin-calibrated from
# the kernel bodies; used by the controller's throttle rule, paper 4.4)
DECOMP_OPS_PER_BYTE = {
    "bdi": 1.0,       # masked add + widen
    "fpc": 2.0,       # pattern select + splice
    "cpack": 2.0,     # dict gather + splice
    "planes": 1.5,    # nibble gather + interleave
    "int8": 1.0,      # scale multiply
    "fp8": 1.0,
    "int4": 1.5,
    "raw": 0.0,
}

LOSSLESS = ("bdi", "fpc", "cpack", "planes")


@dataclasses.dataclass(frozen=True)
class SchemeChoice:
    name: str
    ratio: float
    compressed: Any | None = None


def measure_ratios(x, schemes: tuple[str, ...] = LOSSLESS,
                   keep: bool = False) -> dict[str, SchemeChoice]:
    """Compress ``x`` with each scheme and report true ratios (host-side)."""
    out: dict[str, SchemeChoice] = {}
    for name in schemes:
        if name == "bdi":
            c = bdi.compress_packed(x)
        elif name == "fpc":
            c = fpc.compress(x)
        elif name == "cpack":
            c = cpack.compress(x)
        elif name == "planes":
            if jnp.dtype(x.dtype).itemsize < 2:
                continue
            c = planes.compress(x)
        elif name in ("int8", "fp8", "int4"):
            c = quant.compress(x, name)
        else:
            raise ValueError(name)
        out[name] = SchemeChoice(name, float(c.ratio()), c if keep else None)
    return out


def best_of_all(x, schemes: tuple[str, ...] = LOSSLESS,
                cost_weight: float = 0.0) -> SchemeChoice:
    """Pick argmax ratio (cost_weight=0 reproduces the paper's BestOfAll;
    cost_weight>0 penalizes expensive decompressors per the paper's 7.3
    discussion)."""
    ratios = measure_ratios(x, schemes)
    if not ratios:
        return SchemeChoice("raw", 1.0)
    def score(c: SchemeChoice) -> float:
        return c.ratio - cost_weight * DECOMP_OPS_PER_BYTE[c.name]
    best = max(ratios.values(), key=score)
    if best.ratio <= 1.0:
        return SchemeChoice("raw", 1.0)
    return best

"""Base-Delta-Immediate compression (paper 5.1.1-5.1.2), adapted to TPU blocks.

Faithful elements
-----------------
* A block ("cache line") is viewed as fixed-size words (2/4/8 bytes).
* Encodings: zeros, repeated-value, and {base_bytes}x{delta_bytes} in
  {8x1, 8x2, 8x4, 4x1, 4x2, 2x1}, plus RAW fallback -- the exact set from the
  BDI paper that CABA deploys as assist-warp subroutines.
* Two bases per block: one explicit base (the block's first word -- paper:
  "the first few bytes of the cache line are always used as the base") and an
  implicit zero base; a per-word mask bit selects the base ("Immediate").
* Decompression = masked vector add of deltas to the base (paper Alg. 1) --
  a single VPU-width fused op here.
* Compression tests every encoding in parallel and picks the smallest that
  fits (paper Alg. 2); the per-lane predicate AND across the warp becomes a
  `jnp.all` over the word axis.

TPU adaptations (DESIGN.md 2)
-----------------------------
* Block = 512 B (vs 64 B line): matches VREG/lane tiling, amortizes metadata.
* UNIFORM mode: one encoding for the whole tensor (the paper's own
  single-encoding optimization, 5.1.2) -> static shapes for XLA; chosen at
  compress time outside jit.
* PER-BLOCK mode: per-block encodings with metadata at the head of each
  compressed record (paper 5.1.3 layout) packed into a flat byte stream +
  offset table, consumed by the scalar-prefetch Pallas kernel.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.assist import bytesops as bo

# encoding table: id -> (name, word_bytes, delta_bytes)
# word_bytes == 0 encodes the specials (zeros / rep8 / raw).
ENCODINGS: tuple[tuple[int, str, int, int], ...] = (
    (0, "zeros", 0, 0),
    (1, "rep8", 8, 0),
    (2, "b8d1", 8, 1),
    (3, "b8d2", 8, 2),
    (4, "b8d4", 8, 4),
    (5, "b4d1", 4, 1),
    (6, "b4d2", 4, 2),
    (7, "b2d1", 2, 1),
    (8, "raw", 0, 0),
)
ENC_BY_NAME = {name: (i, wb, db) for i, name, wb, db in ENCODINGS}
RAW_ID = 8
ZEROS_ID = 0
REP8_ID = 1


def enc_size(enc_id: int, block_bytes: int) -> int:
    """Compressed bytes for one block under an encoding (incl. 1 B metadata)."""
    _, name, wb, db = ENCODINGS[enc_id]
    if name == "zeros":
        return 1
    if name == "rep8":
        return 1 + 8
    if name == "raw":
        return 1 + block_bytes
    W = block_bytes // wb
    mask_bytes = -(-W // 8)
    return 1 + wb + mask_bytes + W * db


# ---------------------------------------------------------------------------
# per-block fit analysis (vectorized across all blocks, all encodings)
# ---------------------------------------------------------------------------

def _analyze_word_size(blocks: jax.Array, word_bytes: int):
    """For one word size, which delta widths fit each block (w/ zero base)?

    Returns dict delta_bytes -> bool[nblocks]; plus bool[nblocks] all-equal.
    """
    if word_bytes == 8:
        lo, hi = bo.words_from_block(blocks, 8)
        b_lo, b_hi = lo[..., :1], hi[..., :1]
        d_lo, d_hi = bo.sub64(lo, hi, b_lo, b_hi)
        fits = {}
        for db in (1, 2, 4):
            from_base = bo.fits_signed64(d_lo, d_hi, db)
            from_zero = bo.fits_signed64(lo, hi, db)
            fits[db] = jnp.all(from_base | from_zero, axis=-1)
        all_eq = jnp.all((lo == b_lo) & (hi == b_hi), axis=-1)
        return fits, all_eq
    w = bo.words_from_block(blocks, word_bytes)  # uint32 carriers
    base = w[..., :1]
    delta = w - base  # wraps; for word_bytes<4 we must sign-extend carriers
    if word_bytes < 4:
        # words are zero-extended into uint32; treat them as unsigned values
        # of word_bytes width => delta in [-2^{8wb}+1, 2^{8wb}-1], still fine
        # to range-check as a 32-bit two's-complement quantity.
        pass
    fits = {}
    for db in (1, 2):
        if db >= word_bytes:
            continue
        from_base = bo.fits_signed32(delta, db)
        from_zero = bo.fits_signed32(w, db)
        fits[db] = jnp.all(from_base | from_zero, axis=-1)
    all_eq = jnp.all(w == base, axis=-1)
    return fits, all_eq


def analyze(blocks: jax.Array) -> jax.Array:
    """bool[nblocks, n_encodings]: does encoding e fit block i losslessly?"""
    nblocks, B = blocks.shape
    feasible = [None] * len(ENCODINGS)
    feasible[ZEROS_ID] = jnp.all(blocks == 0, axis=-1)
    fits8, alleq8 = _analyze_word_size(blocks, 8)
    feasible[REP8_ID] = alleq8
    feasible[ENC_BY_NAME["b8d1"][0]] = fits8[1]
    feasible[ENC_BY_NAME["b8d2"][0]] = fits8[2]
    feasible[ENC_BY_NAME["b8d4"][0]] = fits8[4]
    fits4, _ = _analyze_word_size(blocks, 4)
    feasible[ENC_BY_NAME["b4d1"][0]] = fits4[1]
    feasible[ENC_BY_NAME["b4d2"][0]] = fits4[2]
    fits2, _ = _analyze_word_size(blocks, 2)
    feasible[ENC_BY_NAME["b2d1"][0]] = fits2[1]
    feasible[RAW_ID] = jnp.ones((nblocks,), bool)
    return jnp.stack(feasible, axis=-1)


def best_encoding_per_block(blocks: jax.Array,
                            allowed: tuple[int, ...] | None = None) -> jax.Array:
    """int32[nblocks]: smallest feasible encoding id per block (paper Alg. 2).

    ``allowed`` restricts the encoding set (the paper's 'few encodings are
    sufficient' reduction, 5.1.3); RAW is always implicitly allowed.
    """
    B = blocks.shape[-1]
    feas = analyze(blocks)
    sizes = jnp.asarray([enc_size(i, B) for i, *_ in ENCODINGS], jnp.int32)
    cost = jnp.where(feas, sizes, jnp.int32(1 << 30))
    if allowed is not None:
        allow = np.zeros(len(ENCODINGS), bool)
        allow[list(allowed) + [RAW_ID]] = True
        cost = jnp.where(jnp.asarray(allow), cost, jnp.int32(1 << 30))
    return jnp.argmin(cost, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# UNIFORM mode: one encoding per tensor (static shapes; weights path)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("base_lo", "base_hi", "mask", "deltas"),
         meta_fields=("enc_id", "shape", "dtype_name", "block_bytes", "pad"))
@dataclasses.dataclass(frozen=True)
class BDIUniform:
    """BDI-compressed tensor, single encoding (SoA layout, jit-friendly)."""
    base_lo: jax.Array     # uint32[nblocks]
    base_hi: jax.Array     # uint32[nblocks]   (zeros unless 8-byte words)
    mask: jax.Array        # uint8[nblocks, ceil(W/8)]  base-vs-zero selector
    deltas: jax.Array      # uint8[nblocks, W*delta_bytes]
    enc_id: int
    shape: tuple
    dtype_name: str
    block_bytes: int
    pad: int

    @property
    def nblocks(self) -> int:
        return self.base_lo.shape[0]

    def compressed_bytes(self) -> int:
        n = self.nblocks
        _, name, wb, _ = ENCODINGS[self.enc_id]
        base_bytes = wb if wb else 0
        return n * (1 + base_bytes) + self.mask.size + self.deltas.size

    def original_bytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_name).itemsize

    def ratio(self) -> float:
        return self.original_bytes() / max(self.compressed_bytes(), 1)


def _encode_uniform(blocks: jax.Array, enc_id: int):
    """Encode every block with one encoding. Caller guarantees feasibility."""
    nblocks, B = blocks.shape
    _, name, wb, db = ENCODINGS[enc_id]
    if name == "zeros":
        z = jnp.zeros((nblocks,), jnp.uint32)
        return z, z, jnp.zeros((nblocks, 0), jnp.uint8), jnp.zeros((nblocks, 0), jnp.uint8)
    if name == "rep8":
        lo, hi = bo.words_from_block(blocks, 8)
        return lo[:, 0], hi[:, 0], jnp.zeros((nblocks, 0), jnp.uint8), jnp.zeros((nblocks, 0), jnp.uint8)
    if name == "raw":
        z = jnp.zeros((nblocks,), jnp.uint32)
        return z, z, jnp.zeros((nblocks, 0), jnp.uint8), blocks
    W = B // wb
    if wb == 8:
        lo, hi = bo.words_from_block(blocks, 8)
        b_lo, b_hi = lo[:, :1], hi[:, :1]
        d_lo, d_hi = bo.sub64(lo, hi, b_lo, b_hi)
        use_base = bo.fits_signed64(d_lo, d_hi, db)
        # where base does not fit, fall back to the zero base (immediate)
        sel_lo = jnp.where(use_base, d_lo, lo)
        mask = bo.pack_bits(use_base)
        deltas = bo.pack_low_bytes(sel_lo, db)
        return b_lo[:, 0], b_hi[:, 0], mask, deltas
    w = bo.words_from_block(blocks, wb)
    base = w[:, :1]
    d = w - base
    use_base = bo.fits_signed32(d, db)
    sel = jnp.where(use_base, d, w)
    mask = bo.pack_bits(use_base)
    deltas = bo.pack_low_bytes(sel, db)
    return base[:, 0], jnp.zeros_like(base[:, 0]), mask, deltas


def choose_uniform_encoding(x: jax.Array, block_bytes: int = bo.DEFAULT_BLOCK_BYTES) -> int:
    """Smallest encoding feasible for EVERY block (paper's one-encoding opt)."""
    blocks, _ = bo.pad_to_blocks(bo.to_bytes(x), block_bytes)
    # sync-ok: cold-pack encoding choice reads the feasibility vector
    feas_all = np.asarray(jnp.all(analyze(blocks), axis=0))
    sizes = np.asarray([enc_size(i, block_bytes) for i, *_ in ENCODINGS])
    sizes = np.where(feas_all, sizes, 1 << 30)
    return int(np.argmin(sizes))


def compress_uniform(x: jax.Array, enc_id: int | None = None,
                     block_bytes: int = bo.DEFAULT_BLOCK_BYTES) -> BDIUniform:
    """Compress ``x`` with a single tensor-wide encoding (lossless).

    ``enc_id=None`` selects the best feasible encoding (concrete data needed,
    i.e. call outside jit -- this is the paper's host-side initial setup).
    """
    if enc_id is None:
        enc_id = choose_uniform_encoding(x, block_bytes)
    blocks, pad = bo.pad_to_blocks(bo.to_bytes(x), block_bytes)
    base_lo, base_hi, mask, deltas = _encode_uniform(blocks, enc_id)
    return BDIUniform(base_lo=base_lo, base_hi=base_hi, mask=mask,
                      deltas=deltas, enc_id=enc_id, shape=tuple(x.shape),
                      dtype_name=str(x.dtype), block_bytes=block_bytes, pad=pad)


def _decode_uniform_blocks(c: BDIUniform) -> jax.Array:
    """uint8[nblocks, block_bytes] of reconstructed data (paper Alg. 1)."""
    B = c.block_bytes
    _, name, wb, db = ENCODINGS[c.enc_id]
    nblocks = c.nblocks
    if name == "zeros":
        return jnp.zeros((nblocks, B), jnp.uint8)
    if name == "rep8":
        W = B // 8
        lo = jnp.broadcast_to(c.base_lo[:, None], (nblocks, W))
        hi = jnp.broadcast_to(c.base_hi[:, None], (nblocks, W))
        return bo.block_from_words((lo, hi), 8, B)
    if name == "raw":
        return c.deltas
    W = B // wb
    use_base = bo.unpack_bits(c.mask, W)
    if wb == 8:
        d_lo = bo.unpack_low_bytes(c.deltas, W, db)
        d_lo_s = bo.sext32(d_lo, db)
        sign = jnp.where(
            (d_lo_s >> jnp.uint32(31)) == 1, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
        v_lo, v_hi = bo.add64(d_lo_s, sign,
                              c.base_lo[:, None], c.base_hi[:, None])
        lo = jnp.where(use_base, v_lo, d_lo_s)
        hi = jnp.where(use_base, v_hi, sign)
        return bo.block_from_words((lo, hi), 8, B)
    d = bo.unpack_low_bytes(c.deltas, W, db)
    d_s = bo.sext32(d, db)
    v = jnp.where(use_base, d_s + c.base_lo[:, None], d_s)
    # words narrower than the carrier: truncate to the word width
    if wb < 4:
        v = v & jnp.uint32((1 << (8 * wb)) - 1)
    return bo.block_from_words(v, wb, B)


def decompress_uniform(c: BDIUniform) -> jax.Array:
    flat = _decode_uniform_blocks(c).reshape(-1)
    n = int(np.prod(c.shape)) * jnp.dtype(c.dtype_name).itemsize
    return bo.from_bytes(flat[:n], c.dtype_name, c.shape)


# ---------------------------------------------------------------------------
# PER-BLOCK mode: paper-faithful per-line encodings, variable-rate layout
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("stream", "offsets", "enc"),
         meta_fields=("shape", "dtype_name", "block_bytes", "pad",
                      "stream_bytes"))
@dataclasses.dataclass(frozen=True)
class BDIPacked:
    """Variable-rate BDI: records ``[enc | base | mask | deltas]`` head-first
    (paper 5.1.3: metadata at the head of the line), concatenated into one
    byte stream with a per-block offset table (the TPU stand-in for the
    coalescing/address-generation logic the paper leverages)."""
    stream: jax.Array    # uint8[stream_bytes_padded]
    offsets: jax.Array   # int32[nblocks]  byte offset of each record
    enc: jax.Array       # uint8[nblocks]
    shape: tuple
    dtype_name: str
    block_bytes: int
    pad: int
    stream_bytes: int    # true (unpadded) stream length

    @property
    def nblocks(self) -> int:
        return self.enc.shape[0]

    def compressed_bytes(self) -> int:
        return self.stream_bytes + self.offsets.size * 4 + self.enc.size

    def original_bytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype_name).itemsize

    def ratio(self) -> float:
        return self.original_bytes() / max(self.compressed_bytes(), 1)


def _encode_one_block_np(blk: np.ndarray, enc_id: int) -> np.ndarray:
    """Reference (numpy) record encoder for one block; compress-time only."""
    B = blk.shape[0]
    _, name, wb, db = ENCODINGS[enc_id]
    head = np.array([enc_id], np.uint8)
    if name == "zeros":
        return head
    if name == "rep8":
        return np.concatenate([head, blk[:8]])
    if name == "raw":
        return np.concatenate([head, blk])
    W = B // wb
    words = blk.reshape(W, wb)
    asint = np.zeros(W, np.int64)
    for k in range(wb):
        asint |= words[:, k].astype(np.int64) << (8 * k)
    base = asint[0]
    delta = asint - base
    lim = 1 << (8 * db - 1)
    use_base = (delta >= -lim) & (delta < lim)
    sel = np.where(use_base, delta, asint)
    mask = np.packbits(use_base, bitorder="little")
    dbytes = np.zeros((W, db), np.uint8)
    for k in range(db):
        dbytes[:, k] = (sel >> (8 * k)) & 0xFF
    base_bytes = np.array([(base >> (8 * k)) & 0xFF for k in range(wb)], np.uint8)
    return np.concatenate([head, base_bytes, mask, dbytes.reshape(-1)])


def compress_packed(x: jax.Array,
                    block_bytes: int = bo.DEFAULT_BLOCK_BYTES,
                    align: int = 4,
                    allowed: tuple[int, ...] | None = None) -> BDIPacked:
    """Per-block best-encoding compression into a packed stream (host-side)."""
    blocks, pad = bo.pad_to_blocks(bo.to_bytes(x), block_bytes)
    enc = np.asarray(best_encoding_per_block(blocks, allowed), np.int32)
    blocks_np = np.asarray(blocks)
    records = [_encode_one_block_np(blocks_np[i], int(enc[i]))
               for i in range(blocks_np.shape[0])]
    sizes = np.array([-(-len(r) // align) * align for r in records], np.int64)
    offsets = np.zeros(len(records), np.int64)
    offsets[1:] = np.cumsum(sizes)[:-1]
    total = int(offsets[-1] + sizes[-1]) if len(records) else 0
    # pad stream so any record slice of max size stays in bounds
    max_rec = 1 + block_bytes
    stream = np.zeros(total + max_rec, np.uint8)
    for r, off in zip(records, offsets):
        stream[off:off + len(r)] = r
    return BDIPacked(stream=jnp.asarray(stream),
                     offsets=jnp.asarray(offsets, jnp.int32),
                     enc=jnp.asarray(enc.astype(np.uint8)),
                     shape=tuple(x.shape), dtype_name=str(x.dtype),
                     block_bytes=block_bytes, pad=pad, stream_bytes=total)


def decompress_packed(c: BDIPacked) -> jax.Array:
    """jit-friendly decode: every block decodes every-encoding-in-parallel and
    selects -- the SIMT 'all lanes run the subroutine, masked' adaptation."""
    B = c.block_bytes
    max_rec = 1 + B

    def decode_block(off, enc_id):
        rec = jax.lax.dynamic_slice(c.stream, (off,), (max_rec,))
        outs = []
        for eid, name, wb, db in ENCODINGS:
            outs.append(_decode_record(rec, eid, B))
        stacked = jnp.stack(outs)  # [n_enc, B]
        return stacked[enc_id]

    blocks = jax.vmap(decode_block)(c.offsets, c.enc.astype(jnp.int32))
    flat = blocks.reshape(-1)
    n = int(np.prod(c.shape)) * jnp.dtype(c.dtype_name).itemsize
    return bo.from_bytes(flat[:n], c.dtype_name, c.shape)


def _decode_record(rec: jax.Array, enc_id: int, B: int) -> jax.Array:
    """Decode one record (uint8[1+B]) assuming encoding ``enc_id``."""
    _, name, wb, db = ENCODINGS[enc_id]
    if name == "zeros":
        return jnp.zeros((B,), jnp.uint8)
    if name == "rep8":
        return jnp.tile(rec[1:9], B // 8)
    if name == "raw":
        return rec[1:1 + B]
    W = B // wb
    mask_bytes = -(-W // 8)
    base_b = rec[1:1 + wb]
    mask = bo.unpack_bits(rec[1 + wb:1 + wb + mask_bytes], W)
    dbytes = rec[1 + wb + mask_bytes:1 + wb + mask_bytes + W * db]
    d = bo.unpack_low_bytes(dbytes, W, db)
    d_s = bo.sext32(d, db)
    if wb == 8:
        lo32 = (base_b[0].astype(jnp.uint32) | (base_b[1].astype(jnp.uint32) << 8)
                | (base_b[2].astype(jnp.uint32) << 16) | (base_b[3].astype(jnp.uint32) << 24))
        hi32 = (base_b[4].astype(jnp.uint32) | (base_b[5].astype(jnp.uint32) << 8)
                | (base_b[6].astype(jnp.uint32) << 16) | (base_b[7].astype(jnp.uint32) << 24))
        sign = jnp.where((d_s >> jnp.uint32(31)) == 1,
                         jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
        v_lo, v_hi = bo.add64(d_s, sign, lo32, hi32)
        lo = jnp.where(mask, v_lo, d_s)
        hi = jnp.where(mask, v_hi, sign)
        return bo.block_from_words((lo[None], hi[None]), 8, B)[0]
    base = jnp.uint32(0)
    for k in range(wb):
        base = base | (base_b[k].astype(jnp.uint32) << jnp.uint32(8 * k))
    v = jnp.where(mask, d_s + base, d_s)
    if wb < 4:
        v = v & jnp.uint32((1 << (8 * wb)) - 1)
    return bo.block_from_words(v[None], wb, B)[0]


# convenience API ------------------------------------------------------------

def compress(x, mode: str = "uniform", **kw):
    if mode == "uniform":
        return compress_uniform(x, **kw)
    if mode == "packed":
        return compress_packed(x, **kw)
    raise ValueError(mode)


def decompress(c):
    if isinstance(c, BDIUniform):
        return decompress_uniform(c)
    if isinstance(c, BDIPacked):
        return decompress_packed(c)
    raise TypeError(type(c))

"""Compression schemes -- the compress-kind assist payloads (paper 5).

bdi / fpc / cpack are the paper's algorithms; planes / quant are the TPU
additions.  selector implements BestOfAll (paper 7.3).  They are
registered as ``CompressTask``s in ``repro.assist.registry``.
"""
from repro.assist.schemes import bdi, cpack, fpc, planes, quant, selector

__all__ = ["bdi", "cpack", "fpc", "planes", "quant", "selector"]

"""Page-kind taxonomy for the paged decode path (DESIGN.md 10.6).

The paged/tiered machinery originally knew exactly one shape of page:
``page_size`` tokens of per-head attention K/V.  The CABA framing says the
same trigger/throttle/priority machinery should host *many* kinds of
assist work over the same idle resources; for the cache that means many
kinds of *page*:

  attn_kv      per-head K/V of ``page_size`` tokens (GQA / local-window /
               weight-shared attention) -- the original kind
  mla_latent   the absorbed-decode MLA latent: ``kv_lora_rank`` floats of
               compressed KV plus ``rope_head_dim`` floats of shared rope
               key per token, ONE head -- the architecture's own KV
               compression, which the tier ladder's int8/cold packing
               then compounds
  state_slab   the fixed-size recurrence state of an SSM/RWKV layer
               ([H, K, V] + conv / token-shift planes), flattened to one
               NON-GROWING slab per request: allocated once at admission,
               demotable/promotable like any page, int8 when parked

A ``PageKind`` records the three facts the tiered store and the sharing
machinery dispatch on: whether the kind grows with tokens
(page-per-``page_size``-tokens vs one slab per request -- this decides
which slot space and which pool segments a page of that kind occupies),
whether parking it may be lossy (``TieredKVStore.demote_to_warm``
refuses to int8-quantize a kind that declares ``lossy_park=False``), and
whether pages of the kind may be SHARED read-only across requests
(``shareable``).  Token pages are shareable because causal attention
makes a shared token prefix yield identical K/V (or MLA latents)
regardless of suffix; state slabs are NOT -- a recurrence state at
position i summarizes the whole sequence so far and is cheap to park
but meaningless to alias between requests that will diverge.  The
geometry itself (heads,
widths, rows) is per-model and lives in ``repro.cache.tiers.
SegmentGeometry``; this module is the kind registry those descriptors
reference.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PageKind:
    """One kind of page the tiered store can host."""
    name: str
    grows: bool        # True: one page per page_size tokens; False: one
    #                    fixed slab per request
    lossy_park: bool   # demotion to the warm tier quantizes (bounded err);
    #                    False = must park through a lossless path only
    shareable: bool = False  # may one physical page back several
    #                    requests' block tables (refcounted read-only
    #                    prefix sharing + COW)?  Token pages yes; state
    #                    slabs never.


ATTN_KV = PageKind("attn_kv", grows=True, lossy_park=True, shareable=True)
MLA_LATENT = PageKind("mla_latent", grows=True, lossy_park=True,
                      shareable=True)
STATE_SLAB = PageKind("state_slab", grows=False, lossy_park=True,
                      shareable=False)

PAGE_KINDS: dict = {k.name: k for k in (ATTN_KV, MLA_LATENT, STATE_SLAB)}


def page_kind(name: str) -> PageKind:
    try:
        return PAGE_KINDS[name]
    except KeyError:
        raise KeyError(f"unknown page kind {name!r}; "
                       f"registered: {sorted(PAGE_KINDS)}") from None

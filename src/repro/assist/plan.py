"""Compression-site policies: where CABA plugs into a model (DESIGN.md 4).

A policy describes, for one (arch x shape) cell, the set of compression
sites, how many bytes each moves per step, which roofline term each relieves,
and the candidate scheme.  The controller turns policies + roofline terms +
measured compressibility into decisions; the train/serve step factories read
the decisions and wire the compressed paths in.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.assist.tasks import SiteDescriptor


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Static plan consumed by the step factories."""
    weights: str = "raw"        # "raw" | "bdi" | "planes" | "int8" ...
    kv: str = "raw"             # "raw" | "int8" | "int4"
    grads: str = "raw"          # "raw" | "int8" | "fp8"
    acts: str = "raw"           # remat stash: "raw" | "int8"
    opt_state: str = "raw"      # "raw" | "int8"

    def enabled_sites(self) -> list[str]:
        return [f for f in ("weights", "kv", "grads", "acts", "opt_state")
                if getattr(self, f) != "raw"]


RAW_PLAN = CompressionPlan()

# paper-faithful CABA deployment: lossless algorithm on the memory-resident
# read-many data (weights), compression performed host-side at load (5.3.1)
CABA_BDI_PLAN = CompressionPlan(weights="bdi")

# beyond-paper full deployment (documented lossy sites, DESIGN.md 2.3)
CABA_FULL_PLAN = CompressionPlan(weights="planes", kv="int8", grads="fp8",
                                 acts="int8", opt_state="int8")


def sites_for_step(kind: str, *, weight_bytes: float, kv_bytes: float,
                   grad_bytes: float, act_bytes: float) -> list[SiteDescriptor]:
    """Candidate sites per step kind with their per-step byte volumes."""
    sites = []
    if kind in ("train",):
        sites.append(SiteDescriptor("grads", grad_bytes, "collective", False))
        sites.append(SiteDescriptor("acts", act_bytes, "memory", False))
        sites.append(SiteDescriptor("weights", weight_bytes, "memory", True))
    if kind in ("prefill", "decode"):
        sites.append(SiteDescriptor("weights", weight_bytes, "memory", True))
        if kv_bytes > 0:
            sites.append(SiteDescriptor("kv", kv_bytes, "memory", False))
    return sites

"""Sharded, atomic, hash-verified, async checkpointing (+ BDI compression).

Fault-tolerance contract (runtime/fault_tolerance.py builds on this):
  * ATOMIC: a checkpoint directory becomes visible only via rename of a
    completed ``.tmp`` dir; a crash mid-write never corrupts ``latest``.
  * VERIFIED: every array file carries a content hash in the manifest;
    restore re-hashes and refuses corrupt shards.
  * RESHARDABLE: arrays are saved in logical (global) form with their tree
    structure; restore re-sards onto ANY mesh (elastic restarts onto fewer
    healthy hosts re-use the same files).
  * ASYNC: ``save_async`` snapshots to host memory, then writes on a
    background thread -- the train loop's "low-priority assist warp"
    (compression + IO off the critical path, paper 4.4 priority semantics).
  * COMPRESSED: payloads optionally go through the CABA BDI scheme
    (host-side lossless, paper 5.3.1 initial setup) -- checkpoint bytes are
    the paper's DRAM-bandwidth story retargeted at storage bandwidth.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


@dataclasses.dataclass(frozen=True)
class CkptConfig:
    base_dir: str
    compress: bool = False       # BDI-compress payloads (lossless)
    keep: int = 3                # retained checkpoints


def _hash(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()[:16]


def _save_array(path: str, arr: np.ndarray, compress: bool) -> dict:
    """Write one array; returns manifest entry."""
    meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if compress and arr.nbytes >= 4096:
        from repro.assist.schemes import bdi
        # bf16 saved via uint16 view (numpy has no bf16); bitpattern exact
        view = arr
        if arr.dtype == jnp.bfloat16:
            view = np.asarray(jax.lax.bitcast_convert_type(
                jnp.asarray(arr), jnp.uint16))
            meta["bf16_as_u16"] = True
        c = bdi.compress_packed(jnp.asarray(view))
        payload = {"stream": np.asarray(c.stream),
                   "offsets": np.asarray(c.offsets),
                   "enc": np.asarray(c.enc)}
        meta.update(scheme="bdi", block_bytes=c.block_bytes, pad=c.pad,
                    stream_bytes=c.stream_bytes,
                    inner_dtype=c.dtype_name, inner_shape=list(c.shape))
        with open(path, "wb") as f:
            np.savez(f, **payload)
    else:
        meta["scheme"] = "raw"
        with open(path, "wb") as f:
            np.save(f, arr if arr.dtype != jnp.bfloat16 else
                    np.asarray(jax.lax.bitcast_convert_type(
                        jnp.asarray(arr), jnp.uint16)))
            if arr.dtype == jnp.bfloat16:
                meta["bf16_as_u16"] = True
    with open(path, "rb") as f:
        meta["hash"] = _hash(f.read())
    meta["file_bytes"] = os.path.getsize(path)
    meta["logical_bytes"] = arr.nbytes
    return meta


def _load_array(path: str, meta: dict) -> np.ndarray:
    with open(path, "rb") as f:
        raw = f.read()
    if _hash(raw) != meta["hash"]:
        raise IOError(f"checkpoint shard corrupt: {path}")
    if meta["scheme"] == "bdi":
        from repro.assist.schemes import bdi
        z = np.load(path)
        c = bdi.BDIPacked(stream=jnp.asarray(z["stream"]),
                          offsets=jnp.asarray(z["offsets"]),
                          enc=jnp.asarray(z["enc"]),
                          shape=tuple(meta["inner_shape"]),
                          dtype_name=meta["inner_dtype"],
                          block_bytes=meta["block_bytes"], pad=meta["pad"],
                          stream_bytes=meta["stream_bytes"])
        arr = np.asarray(bdi.decompress_packed(c))
    else:
        arr = np.load(path)
    if meta.get("bf16_as_u16"):
        arr = np.asarray(jax.lax.bitcast_convert_type(
            jnp.asarray(arr.astype(np.uint16)), jnp.bfloat16))
    return arr.reshape(meta["shape"])


def save(cfg: CkptConfig, step: int, state) -> str:
    """Synchronous atomic save of a state pytree.  Returns final dir."""
    os.makedirs(cfg.base_dir, exist_ok=True)
    final = os.path.join(cfg.base_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    host_state = jax.tree.map(lambda x: np.asarray(x), state)
    manifest = {"step": step, "arrays": {}}
    for i, (name, leaf) in enumerate(_leaf_paths(host_state)):
        fname = f"arr_{i:05d}.npz"
        manifest["arrays"][name] = dict(
            _save_array(os.path.join(tmp, fname), leaf, cfg.compress),
            file=fname)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # the atomic commit
    _gc(cfg)
    return final


def restore(cfg: CkptConfig, like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional pytree/per-leaf NamedSharding
    for elastic re-mesh (arrays are device_put with the NEW sharding)."""
    d = _dir_for(cfg, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _leaf_paths(like)]
    leaves = []
    for name in names:
        meta = manifest["arrays"][name]
        arr = _load_array(os.path.join(d, meta["file"]), meta)
        leaves.append(arr)
    restored = jax.tree.unflatten(_tree_def(like), leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else
            jnp.asarray(a), restored, shardings)
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored, manifest["step"]


def latest_step(cfg: CkptConfig) -> Optional[int]:
    if not os.path.isdir(cfg.base_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(cfg.base_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def _dir_for(cfg: CkptConfig, step: Optional[int]) -> str:
    if step is None:
        step = latest_step(cfg)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {cfg.base_dir}")
    return os.path.join(cfg.base_dir, f"step_{step:08d}")


def _gc(cfg: CkptConfig):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(cfg.base_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-cfg.keep]:
        shutil.rmtree(os.path.join(cfg.base_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host + background write (one in flight at a time)."""

    def __init__(self, cfg: CkptConfig):
        self.cfg = cfg
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def save(self, step: int, state):
        self.wait()                          # one outstanding save max
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def run():
            try:
                save(self.cfg, step, host_state)
            except Exception as e:          # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            e, self.last_error = self.last_error, None
            raise e

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + AOT-compiles every (architecture x input-shape) cell on the
production meshes -- 16x16 single-pod and 2x16x16 multi-pod -- with
ShapeDtypeStruct inputs (no allocation ever happens).  For each cell it
records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
FLOPs/bytes, and the parsed collective schedule -- the inputs to
EXPERIMENTS.md SS Dry-run and SS Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh single --out experiments/dryrun
  ... --kv-mode int8 --remat none                                 # variants

The two os.environ lines above MUST stay the first statements: jax locks
the device count at first init.
"""
import argparse
import dataclasses
import json
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, skip_reason, cells
from repro.launch.mesh import make_production_mesh, mesh_desc, devices_per_pod
from repro.launch import shardings as SH
from repro.launch.sharding import ShardingRules
from repro.models.model import build_model, input_specs, decode_token_specs
from repro.roofline import analysis as RL
from repro.training.train_loop import TrainConfig, make_train_step
from repro.training.optimizer import OptConfig
from repro.training.grad_compress import GradCompressionConfig


def _specs_like(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               kv_mode: str = "bf16", remat: str = "full",
               grad_compress: bool = False, donate: bool = True,
               weights: str = "bf16", serve_sharding: str = "fsdp",
               ep_major: bool = False):
    """Lower + compile one cell; returns (compiled, report_dict).

    Variant knobs (SS Perf iteration levers):
      kv_mode        bf16 | int8       CABA KV-compression site
      weights        bf16 | int8       CABA weight site (serving paths)
      serve_sharding fsdp | tp         ZeRO-3 vs TP-only weights at serve
      remat          full | none       activation checkpoint policy
      grad_compress  compressed cross-pod gradient collective (train)
    """
    arch = get_arch(arch_name)
    shape = get_shape(shape_name)
    reason = skip_reason(arch, shape)
    if reason:
        return None, {"arch": arch_name, "shape": shape_name,
                      "skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(arch, remat=(remat == "full"))
    t0 = time.time()
    serve_tp = serve_sharding == "tp"

    def _params_specs():
        p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        if weights == "int8":
            from repro.models.quantized import quantize_params
            p = jax.eval_shape(quantize_params, p)
        return p

    with ShardingRules(mesh):
        if shape.kind == "train":
            gcc = (GradCompressionConfig(axis="pod", kind="int8")
                   if (grad_compress and multi_pod) else None)
            tcfg = TrainConfig(opt=OptConfig(), grad_compression=gcc)
            step = make_train_step(model, tcfg, mesh)
            state_specs = jax.eval_shape(
                lambda: _init_state_shapes(model, tcfg, mesh))
            state_sh = SH.train_state_shardings(state_specs, mesh,
                                                ep_major=ep_major)
            batch_specs = input_specs(arch, shape)
            batch_sh = SH.batch_shardings(batch_specs, mesh)
            fn = jax.jit(step,
                         in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_specs, batch_specs)
        elif shape.kind == "prefill":
            batch_specs = input_specs(arch, shape)
            batch_sh = SH.batch_shardings(batch_specs, mesh)
            params_specs = _params_specs()
            params_sh = SH.param_shardings(params_specs, mesh,
                                           serve=serve_tp)

            def prefill_fn(params, batch):
                return model.prefill(params, batch, shape.seq_len,
                                     kv_mode=kv_mode)

            fn = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(params_specs, batch_specs)
        else:  # decode
            params_specs = _params_specs()
            params_sh = SH.param_shardings(params_specs, mesh,
                                           serve=serve_tp)
            state_specs = jax.eval_shape(
                lambda: model.init_state(shape.global_batch, shape.seq_len,
                                         kv_mode=kv_mode, uniform_pos=True))
            state_sh = SH.decode_state_shardings(state_specs, mesh)
            tok_specs = decode_token_specs(arch, shape)
            tok_sh = SH.batch_shardings(tok_specs, mesh)
            fn = jax.jit(model.decode_step,
                         in_shardings=(params_sh, state_sh, tok_sh),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(params_specs, state_specs, tok_specs)

        compiled = lowered.compile()

    t1 = time.time()
    n_dev = int(np.prod(mesh.devices.shape))
    report = RL.analyze(
        compiled, arch=arch_name, shape=shape_name,
        mesh_desc=mesh_desc(mesh), n_devices=n_dev,
        devices_per_pod=devices_per_pod(mesh),
        model_flops=RL.model_flops_estimate(arch, shape))
    out = report.summary()
    out.update(kv_mode=kv_mode, remat=remat, grad_compress=grad_compress,
               weights=weights, serve_sharding=serve_sharding,
               compile_s=round(t1 - t0, 1))
    return compiled, out


def _init_state_shapes(model, tcfg, mesh):
    from repro.training.train_loop import init_train_state
    return init_train_state(model, tcfg, jax.random.PRNGKey(0), mesh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch or all")
    ap.add_argument("--shape", default=None, help="one shape or all")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--kv-mode", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--remat", default="full", choices=("full", "none"))
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--weights", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--serve-sharding", default="fsdp",
                    choices=("fsdp", "tp"))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    arch_names = [args.arch] if args.arch else sorted(ARCHS)
    shape_names = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for aname in arch_names:
        for sname in shape_names:
            for mp in meshes:
                tag = f"{aname}.{sname}.{'multi' if mp else 'single'}" \
                      f".{args.kv_mode}.{args.remat}" \
                      f"{'.gc' if args.grad_compress else ''}" \
                      f"{'.w8' if args.weights == 'int8' else ''}" \
                      f"{'.tp' if args.serve_sharding == 'tp' else ''}"
                try:
                    compiled, rep = lower_cell(
                        aname, sname, multi_pod=mp, kv_mode=args.kv_mode,
                        remat=args.remat, grad_compress=args.grad_compress,
                        weights=args.weights,
                        serve_sharding=args.serve_sharding)
                    if compiled is None:
                        print(f"[skip] {tag}: {rep['skipped']}")
                    else:
                        print(f"[ok]   {tag}: bottleneck={rep['bottleneck']}"
                              f" step={rep['step_time_s']:.4f}s"
                              f" compile={rep['compile_s']}s")
                        if rep.get("memory_analysis"):
                            ma = rep["memory_analysis"]
                            print("       memory_analysis:", ma)
                        print("       cost: flops/dev="
                              f"{rep['hlo_flops_per_dev']:.3e} "
                              f"bytes/dev={rep['hlo_bytes_per_dev']:.3e}")
                    del compiled
                    results.append(rep)
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(rep, f, indent=1)
                except Exception as e:
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                    failures.append((tag, str(e)))
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump({"results": results,
                   "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells processed, {len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""End-to-end serving driver (batched requests, continuous batching).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --max-new 12 --kv-mode int8

    # paged, tiered KV cache (repro.cache): --slots becomes decode lanes,
    # residency is bounded by the HBM budget instead of the slot count
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --paged --hbm-budget-mb 1

    # attention backend for the paged decode step (kernels/decode_attn/
    # ops.py registry): gather (jnp), pallas (bf16 kernel), pallas_int8
    # (tiered kernel, in-VMEM warm dequant)
    ... --paged --attn-backend pallas_int8

Engine construction goes through ``ServeConfig.build()`` (repro.serving.
config): the CLI's flat flags fold into the config's nested ``AssistSpec``
(repro.assist), and ``EngineBase.from_config`` picks the dense or paged
engine -- one construction path for both.
"""
from __future__ import annotations

import argparse
import atexit
import os
import signal
import time

import numpy as np

import dataclasses

from repro.kernels.decode_attn.ops import attn_backend_names
from repro.configs.base import DEFAULT_EOS_ID
from repro.obs import Observability, ObsSpec
from repro.obs.export import SnapshotWriter, serve_metrics
from repro.obs.metrics import REGISTRY
from repro.serving.config import ServeConfig
from repro.serving.engine import Request


def build_engine(scfg: ServeConfig):
    """(engine, model, params) for a ServeConfig.

    Thin alias of :meth:`ServeConfig.build`, kept for callers of the
    pre-assist API.
    """
    return scfg.build()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv-mode", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=DEFAULT_EOS_ID,
                    help="end-of-sequence token id (stops a request)")
    ap.add_argument("--paged", action="store_true",
                    help="use the paged, tiered KV cache (repro.cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-budget-mb", type=float, default=64.0)
    ap.add_argument("--attn-backend", default="gather",
                    choices=attn_backend_names(),
                    help="paged decode attention backend")
    ap.add_argument("--no-interpret", dest="interpret",
                    action="store_false",
                    help="run Pallas backends as real kernels (TPU); "
                         "default is interpret mode (CPU-safe)")
    ap.add_argument("--max-cold-pages", type=int, default=None,
                    help="cap on cold (host-offloaded) page ids; default "
                         "derives from the host budget / HBM pools")
    # cross-request prefix reuse (paged engine; DESIGN.md 14)
    ap.add_argument("--prefix-reuse", action="store_true",
                    help="radix-tree prefix store at admission: shared "
                         "prompt prefixes map read-only pages into new "
                         "requests (COW on divergence), skipping prefill "
                         "on a full hit")
    ap.add_argument("--prefix-max-nodes", type=int, default=512,
                    help="prefix-store node budget (one held page per "
                         "node; LRU leaves evicted past it)")
    ap.add_argument("--prefix-min-pages", type=int, default=1,
                    help="shortest shareable prefix, in full pages")
    # multi-turn sessions (repro.sessions, DESIGN.md 15; paged engine)
    # dest avoids the ServeConfig.sessions field (a SessionSpec): the
    # vars(args)-to-fields filter below must not plant this int there
    ap.add_argument("--sessions", dest="n_sessions", type=int,
                    default=None, metavar="N",
                    help="serve N multi-turn sessions from the seeded "
                         "load generator instead of one-shot requests: "
                         "conversations park between turns and resume "
                         "without re-prefilling history")
    ap.add_argument("--no-session-park", dest="session_park",
                    action="store_false",
                    help="stateless baseline: drop pages between turns "
                         "and re-prefill the full history each turn")
    ap.add_argument("--session-resume", default="auto",
                    choices=("auto", "replay", "reprefill"),
                    help="resume policy for parked sessions (auto = the "
                         "promotion-cost vs re-prefill rule)")
    ap.add_argument("--session-turns", type=float, default=3.0,
                    help="mean turns per generated session")
    # observability (repro.obs, DESIGN.md 13)
    ap.add_argument("--no-obs", action="store_true",
                    help="disable all telemetry (counters, probe, trace): "
                         "the overhead-free hot path")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text on this port at /metrics "
                         "(0 = ephemeral; omit to not serve)")
    ap.add_argument("--snapshot-json", default=None,
                    help="write a periodic JSON metrics snapshot here")
    ap.add_argument("--snapshot-every", type=float, default=10.0,
                    help="snapshot period in seconds")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome trace-event JSON (Perfetto) of "
                         "the run here")
    # resilience (repro.serving.resilience, DESIGN.md 17)
    ap.add_argument("--max-queue", dest="max_queue", type=int, default=None,
                    help="bounded admission queue: above this depth the "
                         "lowest-SLO-class submission is shed with error "
                         "status (interactive sheds last)")
    ap.add_argument("--harvest-timeout", dest="harvest_timeout_s",
                    type=float, default=None, metavar="S",
                    help="surface a hung harvest device_get as a watchdog "
                         "trip after S seconds instead of a silent hang")
    ap.add_argument("--session-store", default=None, metavar="PATH",
                    help="durable session snapshot: restored at startup "
                         "if present, written on SIGTERM/exit after a "
                         "graceful drain (paged engine only)")
    ap.add_argument("--strict-transfers", action="store_true",
                    help="wrap the jitted tick dispatch in "
                         "jax.transfer_guard('disallow'): any implicit "
                         "host<->device transfer in the decode loop "
                         "raises instead of silently syncing")
    args = ap.parse_args(argv)
    fields = {f.name for f in dataclasses.fields(ServeConfig)}
    spec = ObsSpec.off() if args.no_obs else ObsSpec(
        trace=args.trace is not None)
    if args.strict_transfers:
        # composes with --no-obs: the guard is independent of telemetry
        spec = dataclasses.replace(spec, strict_transfers=True)
    scfg = ServeConfig(obs=spec, **{k: v for k, v in vars(args).items()
                                    if k in fields and k != "obs"})

    # the serving entrypoint exports through the PROCESS-GLOBAL registry
    # (library consumers get private ones); /metrics and the snapshot
    # writer read it concurrently with the engine loop
    obs = Observability(spec, registry=None if args.no_obs else REGISTRY)
    srv = writer = None
    if args.metrics_port is not None and not args.no_obs:
        srv = serve_metrics(args.metrics_port)
        print(f"/metrics on http://127.0.0.1:{srv.server_address[1]}/metrics")
    if args.snapshot_json and not args.no_obs:
        writer = SnapshotWriter(args.snapshot_json,
                                every_s=args.snapshot_every).start()

    eng, model, _ = scfg.build(obs=obs)
    cfg = model.cfg

    # crash-safe serving (DESIGN.md 17): restore parked sessions from the
    # durable store, and drain gracefully on SIGTERM/exit -- stop
    # admission, finish in-flight ticks, persist, snapshot metrics
    store_path = args.session_store if scfg.assist.paged else None
    if store_path and os.path.exists(store_path):
        eng.restore(store_path)
        print(f"restored {len(eng._parked_sessions)} parked session(s) "
              f"from {store_path}")
    _drained = []

    def _drain(signum=None, frame=None):
        if _drained:
            return
        _drained.append(True)
        eng.queue.clear()                      # stop admission
        eng.run()                              # finish in-flight ticks
        if store_path:
            from repro.serving.resilience import SnapshotError
            try:
                eng.persist(store_path)
                print(f"sessions persisted -> {store_path}")
            except SnapshotError as e:
                print(f"persist skipped: {e}")
        if writer is not None:
            writer.stop()                      # final metrics snapshot
        if signum is not None:
            raise SystemExit(128 + signum)

    signal.signal(signal.SIGTERM, _drain)
    atexit.register(_drain)
    rng = np.random.default_rng(scfg.seed)
    t0 = time.time()
    if args.n_sessions is not None:
        # trace-driven multi-turn serving (repro.sessions): parked turns
        # keep their pages; goodput is accounted per SLO class
        if not scfg.assist.paged:
            raise SystemExit("--sessions needs --paged (the session "
                             "layer parks pages, not slots)")
        import dataclasses as _dc
        from repro.sessions import SessionManager, make_trace
        sspec = _dc.replace(scfg.session_spec(),
                            resume_policy=args.session_resume)
        traces = make_trace(n_sessions=args.n_sessions, seed=scfg.seed,
                            vocab_size=cfg.vocab_size,
                            page_size=scfg.page_size,
                            max_len=scfg.max_len,
                            mean_turns=args.session_turns,
                            max_new=scfg.max_new)
        mgr = SessionManager(eng, sspec, traces)
        rep = mgr.run()
        dt = time.time() - t0
        n_tok = eng.tokens_generated
        print(f"\n{rep['sessions']} sessions / {rep['turns']} turns, "
              f"{n_tok} tokens in {dt:.1f}s ({n_tok / max(dt, 1e-9):.1f} "
              f"tok/s); resumes: {rep['resumes_replay']} replay / "
              f"{rep['resumes_reprefill']} re-prefill, "
              f"{rep['replayed_tokens']} tokens replayed")
        for cls_name, c in rep["per_class"].items():
            gp = (f"{c['goodput_frac']:.2f}"
                  if c["goodput_frac"] is not None else "n/a")
            print(f"  {cls_name:12s} turns={c['turns']:3d} "
                  f"ok={c['turns_ok']:3d} viol={c['slo_violations']:3d} "
                  f"goodput={gp} p95={c['p95_latency_ticks']} ticks "
                  f"(budget {c['budget_ticks']})")
        done = eng.finished
    else:
        for rid in range(scfg.requests):
            plen = int(rng.integers(4, scfg.max_len - scfg.max_new - 1))
            eng.submit(Request(rid=rid,
                               prompt=list(rng.integers(2, cfg.vocab_size,
                                                        plen)),
                               max_new=scfg.max_new))
        done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:8]:
        print(f"req {r.rid:3d}: prompt={len(r.prompt):3d} tok "
              f"-> {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    aspec = scfg.assist
    mode = (f"paged/{aspec.attn_backend}" if aspec.paged
            else f"kv={aspec.kv}")
    print(f"\n{len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, {mode})")
    stats = eng.stats()
    if "dispatch_p50_ms" in stats:
        print(f"tick dispatch p50/p95/p99 ms: "
              f"{stats['dispatch_p50_ms']:.3f}/"
              f"{stats['dispatch_p95_ms']:.3f}/"
              f"{stats['dispatch_p99_ms']:.3f}  "
              f"exec p50/p95/p99 ms: "
              f"{stats.get('exec_p50_ms', 0.0):.3f}/"
              f"{stats.get('exec_p95_ms', 0.0):.3f}/"
              f"{stats.get('exec_p99_ms', 0.0):.3f} "
              f"({stats.get('exec_samples', 0)} fenced samples)")
    if aspec.paged:
        print(f"cache stats: {stats}")
    if args.trace and eng.obs.tracer is not None:
        eng.obs.tracer.write(args.trace)
        print(f"chrome trace -> {args.trace}")
    if writer is not None:
        writer.stop()
        print(f"metrics snapshot -> {args.snapshot_json}")
    if srv is not None:
        srv.shutdown()
    return done


if __name__ == "__main__":
    main()

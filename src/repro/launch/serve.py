"""End-to-end serving driver (batched requests, continuous batching).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --max-new 12 --kv-mode int8

    # paged, tiered KV cache (repro.cache): --slots becomes decode lanes,
    # residency is bounded by the HBM budget instead of the slot count
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --paged --hbm-budget-mb 1
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv-mode", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="use the paged, tiered KV cache (repro.cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-budget-mb", type=float, default=64.0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.paged:
        from repro.cache import TierConfig
        from repro.serving.paged_engine import PagedEngine
        tier = TierConfig(page_size=args.page_size,
                          hbm_budget_bytes=int(args.hbm_budget_mb * 2 ** 20))
        eng = PagedEngine(model, params, lanes=args.slots,
                          max_len=args.max_len, tier=tier, eos_id=0)
    else:
        eng = Engine(model, params, batch_slots=args.slots,
                     max_len=args.max_len, kv_mode=args.kv_mode, eos_id=0)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.max_len - args.max_new - 1))
        eng.submit(Request(rid=rid,
                           prompt=list(rng.integers(2, cfg.vocab_size,
                                                    plen)),
                           max_new=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:8]:
        print(f"req {r.rid:3d}: prompt={len(r.prompt):3d} tok "
              f"-> {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    mode = "paged" if args.paged else f"kv={args.kv_mode}"
    print(f"\n{len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, {mode})")
    if args.paged:
        print(f"cache stats: {eng.stats()}")
    return done


if __name__ == "__main__":
    main()

"""End-to-end serving driver (batched requests, continuous batching).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --max-new 12 --kv-mode int8

    # paged, tiered KV cache (repro.cache): --slots becomes decode lanes,
    # residency is bounded by the HBM budget instead of the slot count
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --paged --hbm-budget-mb 1

    # attention backend for the paged decode step (kernels/decode_attn/
    # ops.py registry): gather (jnp), pallas (bf16 kernel), pallas_int8
    # (tiered kernel, in-VMEM warm dequant)
    ... --paged --attn-backend pallas_int8

Engine construction goes through ``ServeConfig.build()`` (repro.serving.
config): the CLI's flat flags fold into the config's nested ``AssistSpec``
(repro.assist), and ``EngineBase.from_config`` picks the dense or paged
engine -- one construction path for both.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.kernels.decode_attn.ops import attn_backend_names
from repro.configs.base import DEFAULT_EOS_ID
from repro.serving.config import ServeConfig
from repro.serving.engine import Request


def build_engine(scfg: ServeConfig):
    """(engine, model, params) for a ServeConfig.

    Thin alias of :meth:`ServeConfig.build`, kept for callers of the
    pre-assist API.
    """
    return scfg.build()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv-mode", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=DEFAULT_EOS_ID,
                    help="end-of-sequence token id (stops a request)")
    ap.add_argument("--paged", action="store_true",
                    help="use the paged, tiered KV cache (repro.cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-budget-mb", type=float, default=64.0)
    ap.add_argument("--attn-backend", default="gather",
                    choices=attn_backend_names(),
                    help="paged decode attention backend")
    ap.add_argument("--no-interpret", dest="interpret",
                    action="store_false",
                    help="run Pallas backends as real kernels (TPU); "
                         "default is interpret mode (CPU-safe)")
    ap.add_argument("--max-cold-pages", type=int, default=None,
                    help="cap on cold (host-offloaded) page ids; default "
                         "derives from the host budget / HBM pools")
    args = ap.parse_args(argv)
    scfg = ServeConfig(**vars(args))     # argparse dests match field names

    eng, model, _ = scfg.build()
    cfg = model.cfg
    rng = np.random.default_rng(scfg.seed)
    t0 = time.time()
    for rid in range(scfg.requests):
        plen = int(rng.integers(4, scfg.max_len - scfg.max_new - 1))
        eng.submit(Request(rid=rid,
                           prompt=list(rng.integers(2, cfg.vocab_size,
                                                    plen)),
                           max_new=scfg.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:8]:
        print(f"req {r.rid:3d}: prompt={len(r.prompt):3d} tok "
              f"-> {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    spec = scfg.assist
    mode = (f"paged/{spec.attn_backend}" if spec.paged
            else f"kv={spec.kv}")
    print(f"\n{len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, {mode})")
    if spec.paged:
        print(f"cache stats: {eng.stats()}")
    return done


if __name__ == "__main__":
    main()

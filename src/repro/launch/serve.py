"""End-to-end serving driver (batched requests, continuous batching).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --max-new 12 --kv-mode int8

    # paged, tiered KV cache (repro.cache): --slots becomes decode lanes,
    # residency is bounded by the HBM budget instead of the slot count
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 16 --slots 4 --paged --hbm-budget-mb 1

    # attention backend for the paged decode step (kernels/decode_attn/
    # ops.py registry): gather (jnp), pallas (bf16 kernel), pallas_int8
    # (tiered kernel, in-VMEM warm dequant)
    ... --paged --attn-backend pallas_int8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import get_arch, reduced as reduce_cfg
from repro.kernels.decode_attn.ops import attn_backend_names
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Declarative serving configuration (CLI flags map 1:1).

    ``attn_backend`` picks the paged decode attention implementation from
    the kernels/decode_attn/ops.py registry; it only applies with
    ``paged=True``.
    """
    arch: str
    reduced: bool = False
    requests: int = 8
    slots: int = 4                  # dense: batch slots; paged: decode lanes
    max_len: int = 128
    max_new: int = 12
    kv_mode: str = "bf16"           # dense engine cache mode (bf16 | int8)
    seed: int = 0
    paged: bool = False
    page_size: int = 16
    hbm_budget_mb: float = 64.0
    attn_backend: str = "gather"


def build_engine(scfg: ServeConfig):
    """(engine, model, params) for a ServeConfig."""
    cfg = get_arch(scfg.arch)
    if scfg.reduced:
        cfg = reduce_cfg(cfg)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(scfg.seed))
    if scfg.paged:
        from repro.cache import TierConfig
        from repro.serving.paged_engine import PagedEngine
        tier = TierConfig(page_size=scfg.page_size,
                          hbm_budget_bytes=int(scfg.hbm_budget_mb * 2 ** 20))
        eng = PagedEngine(model, params, lanes=scfg.slots,
                          max_len=scfg.max_len, tier=tier, eos_id=0,
                          backend=scfg.attn_backend)
    else:
        eng = Engine(model, params, batch_slots=scfg.slots,
                     max_len=scfg.max_len, kv_mode=scfg.kv_mode, eos_id=0)
    return eng, model, params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--kv-mode", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="use the paged, tiered KV cache (repro.cache)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--hbm-budget-mb", type=float, default=64.0)
    ap.add_argument("--attn-backend", default="gather",
                    choices=attn_backend_names(),
                    help="paged decode attention backend")
    args = ap.parse_args(argv)
    scfg = ServeConfig(**vars(args))     # argparse dests match field names

    eng, model, _ = build_engine(scfg)
    cfg = model.cfg
    rng = np.random.default_rng(scfg.seed)
    t0 = time.time()
    for rid in range(scfg.requests):
        plen = int(rng.integers(4, scfg.max_len - scfg.max_new - 1))
        eng.submit(Request(rid=rid,
                           prompt=list(rng.integers(2, cfg.vocab_size,
                                                    plen)),
                           max_new=scfg.max_new))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid)[:8]:
        print(f"req {r.rid:3d}: prompt={len(r.prompt):3d} tok "
              f"-> {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    mode = (f"paged/{scfg.attn_backend}" if scfg.paged
            else f"kv={scfg.kv_mode}")
    print(f"\n{len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, {mode})")
    if scfg.paged:
        print(f"cache stats: {eng.stats()}")
    return done


if __name__ == "__main__":
    main()

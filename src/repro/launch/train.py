"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Composes the full substrate: config -> model -> data pipeline -> train step
(optionally CABA-compressed grads / int8 opt state) -> supervisor
(checkpoint/restart, straggler detection) -> metrics log.

On this CPU container use ``--reduced`` (same-family small config); the
full configs are exercised via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from repro.assist import AssistSpec
from repro.configs import get_arch, reduced as reduce_cfg
from repro.configs.base import DEFAULT_EOS_ID
from repro.configs.base import ShapeConfig
from repro.data.pipeline import arch_batch
from repro.models.model import build_model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, make_train_step,
                                       init_train_state)
from repro.checkpoint.ckpt import CkptConfig
from repro.runtime.fault_tolerance import Supervisor, SupervisorConfig
from repro.launch.sharding import ShardingRules
from repro.launch.mesh import make_mesh_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opt-compression", default=None,
                    choices=(None, "int8"))
    ap.add_argument("--grad-compress-axis", default=None,
                    help="mesh axis for compressed grad collective")
    ap.add_argument("--grad-compress-kind", default="int8",
                    choices=("int8", "fp8"),
                    help="grad-collective scheme (with --grad-compress-axis)")
    ap.add_argument("--eos-id", type=int, default=DEFAULT_EOS_ID,
                    help="document-separator token in the synthetic stream")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = build_model(cfg)

    mesh = None
    if args.grad_compress_axis:
        n = len(jax.devices())
        mesh = make_mesh_for(n, model=1, pod=2 if n % 2 == 0 else 1)

    # declarative assist sites: the train loop derives the concrete
    # grad-collective / optimizer-state knobs from this spec
    spec = AssistSpec(
        grads=args.grad_compress_kind if args.grad_compress_axis else "raw",
        grad_axis=args.grad_compress_axis or "pod",
        opt_state=args.opt_compression or "raw")
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                      decay_steps=args.steps),
        grad_accum=args.grad_accum, assist=spec)

    step_fn = jax.jit(make_train_step(model, tcfg, mesh))
    data_fn = lambda s: arch_batch(cfg, shape, s, seed=args.seed,
                                   eos_id=args.eos_id)

    def mk_state():
        return init_train_state(model, tcfg, jax.random.PRNGKey(args.seed),
                                mesh)

    sup = Supervisor(
        SupervisorConfig(ckpt=CkptConfig(base_dir=args.ckpt_dir,
                                         compress=True),
                         ckpt_every=args.ckpt_every),
        init_state=mk_state, step_fn=step_fn, data_fn=data_fn)

    ctx = ShardingRules(mesh) if mesh is not None else _null_ctx()
    with ctx:
        t0 = time.time()
        sup.run(args.steps)
    for h in sup.history:
        if h["step"] % args.log_every == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:5d} loss={h['loss']:.4f} "
                  f"grad_norm={h['grad_norm']:.3f} {h['time']*1e3:.0f}ms")
    dt = time.time() - t0
    n_tok = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.0f} tok/s); restarts={sup.restarts}")
    return sup


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

"""Logical-axis sharding: the single place where model code meets the mesh.

Model code annotates tensors with LOGICAL axis names (``"batch"``,
``"embed"``, ``"heads"``, ``"expert"``, ...).  A :class:`ShardingRules`
context maps logical names to mesh axes; outside a context every annotation
is a no-op, so the same model code runs on 1 CPU device (smoke tests) and on
the 512-chip production mesh (dry-run) unchanged.

This is the MaxText/Flaxformer "logical axis rules" pattern, reduced to a
contextvar + two functions.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> mesh axis (or tuple of axes, or None=replicate)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),     # global batch (DP; pod axis exists multi-pod)
    "fsdp": "data",               # ZeRO-3 weight sharding axis
    "model": "model",             # TP axis (heads / ffn / vocab / experts)
    "seq": None,                  # sequence: replicated by default (SP opt-in)
    "expert": "model",            # EP shares the TP axis
    None: None,
}

_ACTIVE: contextvars.ContextVar[Optional["ShardingRules"]] = \
    contextvars.ContextVar("sharding_rules", default=None)


class ShardingRules:
    """Mesh + logical->physical mapping, entered as a context manager."""

    def __init__(self, mesh: Mesh, rules: dict | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        # drop mappings to mesh axes that don't exist (e.g. "pod" single-pod)
        names = set(mesh.axis_names)

        def fix(v):
            if v is None:
                return None
            if isinstance(v, tuple):
                kept = tuple(a for a in v if a in names)
                return kept if kept else None
            return v if v in names else None

        self.rules = {k: fix(v) for k, v in self.rules.items()}
        self._token = None

    def spec(self, *logical) -> P:
        return P(*(self.rules.get(ax, None) for ax in logical))

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def __enter__(self):
        self._token = _ACTIVE.set(self)
        return self

    def __exit__(self, *exc):
        _ACTIVE.reset(self._token)
        return False


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE.get()


def manual_shard_map(f, mesh, manual_axes, in_specs, out_specs, *,
                     auto_rest: bool = True):
    """shard_map MANUAL over ``manual_axes`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., axis_names=...)``; jax 0.4.x has
    ``jax.experimental.shard_map.shard_map(..., auto=...)``.  With
    ``auto_rest`` the remaining mesh axes stay under GSPMD (partial-manual).
    CAUTION on 0.4.x: XLA's SPMD partitioner cannot partition a while loop
    (``lax.scan``) inside a partial-manual region (``IsManualSubgroup``
    check failure) -- bodies with control flow must pass
    ``auto_rest=False`` (fully manual; unmentioned axes compute
    redundantly) or keep the scan outside the manual region.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        kw = {"axis_names": manual} if auto_rest else {}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual if auto_rest else frozenset()
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      auto=auto)


def shard(x, *logical):
    """Annotate ``x`` with logical axes; no-op without active rules.

    Inside a partial-manual shard_map (compressed-grad path) the manual
    axes are stripped from the constraint: the body sees per-shard values,
    so constraining them on the manual axis would make GSPMD insert bogus
    cross-axis reshards.  Manual axes are read off the tracer's VMA.
    """
    r = _ACTIVE.get()
    if r is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = r.spec(*logical)
    try:
        manual = jax.typeof(x).vma
    except (AttributeError, TypeError):
        manual = frozenset()
    if manual:
        def strip(entry):
            if entry is None:
                return None
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a not in manual)
                return kept if kept else None
            return None if entry in manual else entry
        spec = P(*(strip(e) for e in spec))
        # inside shard_map the constraint must carry the trace-time mesh,
        # whose manual axes are typed Manual
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(jax.sharding.get_abstract_mesh(), spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec))


def logical_sharding(*logical) -> Optional[NamedSharding]:
    """NamedSharding for the active rules (None outside a context)."""
    r = _ACTIVE.get()
    if r is None:
        return None
    return r.sharding(*logical)


def match_vma(x, ref):
    """Make ``x`` vary over the same manual axes as ``ref``.

    Under partial-manual shard_map (the compressed-gradient path), scan
    carries initialized from constants are VMA-invariant while the scanned
    computation is axis-varying; JAX requires carry in/out types to match.
    This pcasts the init to the reference's variance and is a no-op outside
    shard_map.  Applied where model code creates scan carries.
    """
    try:
        vma_ref = jax.typeof(ref).vma
        vma_x = jax.typeof(x).vma
    except (AttributeError, TypeError):
        return x
    need = tuple(a for a in vma_ref if a not in vma_x)
    if not need:
        return x
    return jax.lax.pcast(x, need, to="varying")


def match_vma_tree(tree, ref_leaf):
    return jax.tree.map(lambda t: match_vma(t, ref_leaf), tree)


def shard_attn_qkv(q, k, v):
    """Adaptive attention sharding for full-sequence (train/prefill) paths.

    q: [B,H,Sq,dh]; k/v: [B,G,Sk,*].  If the head count divides the model
    axis, shard heads (Megatron).  Otherwise shard the QUERY sequence over
    model and replicate K/V there (sequence-parallel attention): every
    score/softmax op stays local.  Without this, GSPMD partial-sums the
    f32 logits of misaligned-head archs over a subgroup -- 2.5 TB/step on
    qwen2-7b prefill (SS Perf, dense-cells fix).
    """
    r = _ACTIVE.get()
    if r is None:
        return q, k, v
    model = r.rules.get("model")
    if model is None:
        return q, k, v
    sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
    msize = sizes.get(model, 1)
    B, H, Sq = q.shape[0], q.shape[1], q.shape[2]
    G = k.shape[1]
    if H % msize == 0 and G % msize == 0:
        q = shard(q, "batch", "model", None, None)
        k = shard(k, "batch", "model", None, None)
        v = shard(v, "batch", "model", None, None)
    elif Sq % msize == 0:
        q = shard(q, "batch", None, "model", None)
        k = shard(k, "batch", None, None, None)   # replicated over model
        v = shard(v, "batch", None, None, None)
    return q, k, v

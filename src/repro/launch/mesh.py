"""Production mesh construction.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax init; smoke tests
see 1 device).

Mesh topology (DESIGN.md 6):
  single-pod: (data=16, model=16)            = 256 chips (one v5e pod)
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips, pod axis on DCN
"""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "Run under launch/dryrun.py (it sets "
            "--xla_force_host_platform_device_count=512).")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_mesh_for(n_devices: int, *, model: int = 16, pod: int = 1):
    """Arbitrary mesh (elastic restarts, tests on 8 fake devices)."""
    data = n_devices // (model * pod)
    assert data * model * pod == n_devices, (n_devices, model, pod)
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    arr = np.asarray(jax.devices()[:n_devices]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def mesh_desc(mesh) -> str:
    return "x".join(f"{a}={s}" for a, s in
                    zip(mesh.axis_names, mesh.devices.shape))


def devices_per_pod(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "pod" not in sizes:
        return 0                      # single pod: nothing crosses DCN
    return int(np.prod([s for a, s in sizes.items() if a != "pod"]))
"""Concrete sharding rules for params, optimizer state, decode state, batch.

DESIGN.md 6: 2-D weight sharding -- ZeRO-3/FSDP over ``data``, Megatron TP
over ``model``; the ``pod`` axis carries only DP.  Rules are name-based on
pytree paths, with the base (unstacked) spec per leaf name; leaves carrying
an extra leading scan axis get ``None`` prepended automatically.  Every
axis assignment is divisibility-checked against the mesh -- a dimension
that does not divide falls back to replication (the dry-run must compile
for every (arch x shape), including awkward head counts).
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fits(dim: int, axis, sizes: dict) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        need = int(np.prod([sizes.get(a, 1) for a in axis]))
    else:
        need = sizes.get(axis, 1)
    return dim % need == 0 and dim >= need


def _check(spec_entries, shape, sizes):
    """Drop axis assignments that don't divide their dimension."""
    out = []
    for dim, ax in zip(shape, spec_entries):
        out.append(ax if _fits(dim, ax, sizes) else None)
    return tuple(out)


def batch_axes(mesh: Mesh):
    """The DP axes present in this mesh: ("pod","data") or ("data",)."""
    names = set(mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in names)


# ---------------------------------------------------------------------------
# parameter rules: (path regex, base spec entries)   FS = fsdp axis = "data"
# ---------------------------------------------------------------------------

FS = "data"
TP = "model"

_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"\['embed'\]$",            (TP, FS)),       # [V, D]
    (r"\['unembed'\]$",          (FS, TP)),       # [D, V]
    # MLA
    (r"\['wq_a'\]$",             (FS, None)),
    (r"\['wq_b'\]$",             (None, TP)),
    (r"\['wkv_a'\]$",            (FS, None)),
    (r"\['wkv_b'\]$",            (None, TP)),
    # MoE experts (3-D) -- EP over model
    (r"\['ffn'\]\['wi'\]$",      ("moe3",)),
    (r"\['ffn'\]\['wg'\]$",      ("moe3",)),
    (r"\['ffn'\]\['wo'\]$",      ("moe3o",)),
    (r"\['router'\]$",           (FS, None)),
    # rwkv channel-mix value projection [F, D]
    (r"\['cm'\]\['wv'\]$",       (TP, FS)),
    # generic projections
    (r"\['w[qkvgi]'\]$",         (FS, TP)),       # wq wk wv wg wi [D, F]
    (r"\['wo'\]$",               (TP, FS)),       # [F, D]
    (r"\['wr'\]$",               (FS, TP)),
    (r"\['in_proj'\]$",          (FS, TP)),
    (r"\['out_proj'\]$",         (TP, FS)),
    (r"\['conv_w'\]$",           (None, TP)),
    (r"\['lora_A'\]$",           (FS, None)),
    (r"\['lora_B'\]$",           (None, FS)),
    (r"\['u'\]$",                (TP, None)),     # [H, dh]
]


def _param_base_spec(path_str: str, shape, sizes, *,
                     serve: bool = False, ep_major: bool = False) -> tuple:
    """``serve=True``: TP-only (FSDP axis dropped -> weights replicated over
    ``data``); serving reads weights every step, so per-step all-gathers of
    ZeRO-3 shards would dominate the decode roofline (SS Perf iteration).

    ``ep_major=True``: the ``model`` axis is reserved for EXPERTS (EP) and
    the vocab; dense/attention projections drop their TP axis (FSDP only).
    Removes the per-layer [B,S,D] tensor-parallel psums that dominate MoE
    training collectives (SS Perf it4) at the cost of wider per-device
    dense matmuls."""
    nd = len(shape)
    # compressed-weight leaves: rule of the parent tensor name
    path_str = re.sub(r"\['(q8|s8)'\]$", "", path_str)
    if nd <= 1:
        return (None,) * nd                       # norms, biases, scalars
    is_expert = bool(re.search(r"\['ffn'\]\['w[igo]'\]$", path_str))
    is_vocab = bool(re.search(r"\['(embed|unembed)'\]$", path_str))
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            if spec == ("moe3",):                 # [E, D, F]
                base = (TP, FS, None)
            elif spec == ("moe3o",):              # [E, F, D]
                base = (TP, None, FS)
            else:
                base = spec
            if nd == len(base) + 1:               # scan-stacked
                base = (None,) + tuple(base)
            if nd != len(base):
                return (None,) * nd
            if serve:
                base = tuple(None if a == FS else a for a in base)
            if ep_major and not (is_expert or is_vocab):
                base = tuple(None if a == TP else a for a in base)
            return _check(base, shape, sizes)
    # default 2-D: fsdp x model; higher rank: replicate
    if nd == 2:
        base = (None, TP) if serve else (FS, TP)
    elif nd == 3:
        base = (None, None, TP) if serve else (None, FS, TP)
    else:
        return (None,) * nd
    if ep_major and not (is_expert or is_vocab):
        base = tuple(None if a == TP else a for a in base)
    return _check(base, shape, sizes)


def param_shardings(params_shape, mesh: Mesh, *, serve: bool = False):
    """Pytree of NamedShardings mirroring ``params_shape`` (ShapeDtypeStructs
    or arrays)."""
    sizes = axis_sizes(mesh)

    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        spec = _param_base_spec(ps, leaf.shape, sizes, serve=serve)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# optimizer / train-state shardings
# ---------------------------------------------------------------------------

def train_state_shardings(state_shape, mesh: Mesh, *, ep_major: bool = False):
    """params/master/m/v follow param rules; residual is pod-sharded;
    scalars replicate."""
    sizes = axis_sizes(mesh)

    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        if ps.startswith("['residual']"):
            ax = "pod" if "pod" in sizes else "data"
            return NamedSharding(mesh, P(ax))
        if leaf.ndim == 0 or "count" in ps:
            return NamedSharding(mesh, P())
        # strip the state prefix so param rules match
        spec = _param_base_spec(ps, leaf.shape, sizes, ep_major=ep_major)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shape)


# ---------------------------------------------------------------------------
# batch / decode-state shardings
# ---------------------------------------------------------------------------

def batch_shardings(batch_shape, mesh: Mesh):
    sizes = axis_sizes(mesh)
    dp = batch_axes(mesh)

    def one(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        first = dp if _fits(b, dp, sizes) else (
            "data" if _fits(b, "data", sizes) else None)
        spec = (first,) + (None,) * (leaf.ndim - 1) if leaf.ndim else ()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def decode_state_shardings(state_shape, mesh: Mesh):
    """Decode caches: batch over DP axes; heads (preferred) or sequence
    over ``model``.  Per-leaf divisibility-checked -- awkward dims fall back
    to replication so every (arch x shape) cell compiles.

    Layouts handled (leading scan axis auto-detected via the 'scan' key):
      k/v/k8/v8 [B, G, W, dh]   G->model, else W->model (flash-decode)
      ks/vs     [B, G, W]       matches k8/v8 choice
      c/c8/r    [B, W, X]       W->model (MLA latent)
      cs        [B, W]          W->model
      h         [B, H, K, P]    H->model   (mamba2)
      wkv       [B, H, k, v]    H->model   (rwkv6)
      conv      [B, dc, ch]     ch->model
      tm_prev/cm_prev [B, D]    D->model
      pos_arr/len               batch only / replicated
    """
    sizes = axis_sizes(mesh)
    dp = batch_axes(mesh)

    def bspec(b):
        return dp if _fits(b, dp, sizes) else (
            "data" if _fits(b, "data", sizes) else None)

    def one(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        scanned = "scan" in keys
        shape = leaf.shape[1:] if scanned else leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if nd >= 1 and name != "":
            spec[0] = bspec(shape[0])
        if name in ("k", "v", "k8", "v8") and nd == 4:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP                      # heads over model
            elif _fits(shape[2], TP, sizes):
                spec[2] = TP                      # sequence over model
        elif name in ("ks", "vs") and nd == 3:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP
            elif _fits(shape[2], TP, sizes):
                spec[2] = TP
        elif name in ("c", "c8", "r") and nd == 3:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP
        elif name == "cs" and nd == 2:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP
        elif name in ("h", "wkv") and nd == 4:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP
        elif name == "conv" and nd == 3:
            if _fits(shape[2], TP, sizes):
                spec[2] = TP
        elif name in ("tm_prev", "cm_prev") and nd == 2:
            if _fits(shape[1], TP, sizes):
                spec[1] = TP
        if scanned:
            spec = [None] + spec
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shape)

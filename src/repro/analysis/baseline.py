"""Finding baselines: grandfather what exists, fail on what is new.

The baseline file (``analysis_baseline.json`` at the repo root) holds
the fingerprints of known findings.  ``tools/check.py --compare`` fails
only on fingerprints NOT in the file, so a rule can land stricter than
the current code without blocking CI -- but the goal state (and the
shipped state for ``serving/`` and ``cache/``) is an EMPTY baseline:
every real finding fixed or pragma'd, nothing grandfathered.

``pragma-no-reason`` findings are never baselineable: an exemption
without a reason is a process violation, not technical debt.
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.findings import PRAGMA_NO_REASON, Finding

BASELINE_VERSION = 1


def load_baseline(path) -> set:
    """Fingerprint set from a baseline file (empty if absent)."""
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    data = json.loads(p.read_text())
    return {f["fingerprint"] for f in data.get("findings", [])}


def save_baseline(path, findings) -> None:
    """Write the grandfather file for the given findings (sorted,
    reason-less pragmas excluded -- those must be fixed, not recorded)."""
    by_fp = {f.fingerprint(): f for f in findings
             if f.rule != PRAGMA_NO_REASON}
    records = [
        {"fingerprint": fp, "rule": f.rule, "path": f.path,
         "qualname": f.qualname, "message": f.message}
        for fp, f in sorted(by_fp.items())]
    pathlib.Path(path).write_text(json.dumps(
        {"version": BASELINE_VERSION, "findings": records},
        indent=2, sort_keys=True) + "\n")


def new_findings(findings, baseline_fps) -> list:
    """Findings not covered by the baseline.  ``pragma-no-reason`` is
    always new by design."""
    return [f for f in findings
            if f.rule == PRAGMA_NO_REASON
            or f.fingerprint() not in baseline_fps]

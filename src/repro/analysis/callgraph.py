"""Name-based call graph over the repo's ASTs (DESIGN.md 16).

Python offers no sound static call resolution, so the sanitizer
over-approximates on purpose: a call ``self.f()`` / ``obj.f()`` /
``f()`` reaches *every* definition named ``f`` anywhere in the scanned
tree.  Over-approximation errs toward scanning too much code with the
hot-path rules -- strictly safe for a linter whose job is catching
accidental host syncs (a missed edge would be a silent hole; a spurious
edge is at worst a pragma).

Nested ``def``s (the jit bodies built inside ``__init__``) index under
their parent's qualname but are only reachable through an explicit
name reference; the tick never calls them by name (they are dispatched
through jitted attributes), so trace-time code stays out of host-sync
scope -- Python control flow on tracers inside a jit already fails at
trace time and needs no linter.
"""
from __future__ import annotations

import ast
import collections
import dataclasses
from typing import Optional


@dataclasses.dataclass
class FuncInfo:
    qualname: str              # e.g. "PagedEngine.step", "prompt_bucket"
    name: str                  # bare name, the resolution key
    cls: Optional[str]         # enclosing class, if a method
    path: str                  # repo-relative path of the defining module
    node: ast.AST              # the FunctionDef
    calls: set = dataclasses.field(default_factory=set)   # called names


def _called_names(fn: ast.AST) -> set:
    """Bare names this function calls (or references, for the local
    nested-def case), excluding nested function bodies."""
    names: set = set()
    nested = {n.name for n in ast.walk(fn)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
              and n is not fn}
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                names.add(f.id)
            elif isinstance(f, ast.Attribute):
                names.add(f.attr)
        elif isinstance(node, ast.Name) and node.id in nested:
            names.add(node.id)           # e.g. jax.jit(step_fn)
    return names


class SymbolIndex:
    """Every function/method definition in the scanned tree, resolvable
    by bare name, plus reachability from a set of root methods."""

    def __init__(self):
        self.funcs: dict[str, FuncInfo] = {}
        self.by_name: dict[str, list[str]] = collections.defaultdict(list)

    def add_module(self, path: str, tree: ast.Module):
        def add(fn: ast.AST, qual: str, cls: Optional[str]):
            fi = FuncInfo(qualname=f"{path}::{qual}", name=fn.name,
                          cls=cls, path=path, node=fn,
                          calls=_called_names(fn))
            self.funcs[fi.qualname] = fi
            self.by_name[fi.name].append(fi.qualname)
            for child in ast.walk(fn):
                if (isinstance(child, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
                        and child is not fn
                        and getattr(child, "_cg_seen", False) is False):
                    child._cg_seen = True
                    add(child, f"{qual}.{child.name}", cls)

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                node._cg_seen = True
                add(node, node.name, None)
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        item._cg_seen = True
                        add(item, f"{node.name}.{item.name}", node.name)

    def roots(self, root_specs) -> list[str]:
        """Qualnames matching (class, method) root specs.  ``class`` of
        None matches module-level functions of that name."""
        out = []
        for cls, name in root_specs:
            for qual in self.by_name.get(name, ()):
                fi = self.funcs[qual]
                if fi.cls == cls or (cls is not None and fi.cls is not None
                                     and fi.cls == cls):
                    out.append(qual)
        return out

    def reachable(self, root_specs) -> set:
        """Qualnames reachable from the roots through the by-name graph."""
        seen: set = set()
        work = list(self.roots(root_specs))
        while work:
            qual = work.pop()
            if qual in seen:
                continue
            seen.add(qual)
            fi = self.funcs[qual]
            for name in fi.calls:
                for callee in self.by_name.get(name, ()):
                    if callee not in seen:
                        work.append(callee)
        return seen

"""The hot-path sanitizer's rule catalog (DESIGN.md 16).

Four invariant families over ``src/repro`` (each PR 5-8 property that is
otherwise just a convention), all AST-level, no imports of the checked
code:

hot-sync / hot-branch   no host sync (``jax.device_get``,
                        ``block_until_ready``, ``.item()``, or
                        ``int``/``float``/``bool``/``np.asarray`` of a
                        device value) and no Python ``if``/``while`` on
                        a device value inside functions reachable from
                        the engine ``step`` roots.  Sanctioned syncs
                        carry a ``# sync-ok: <reason>`` pragma.
metrics-name/-bind/-label
                        registry names match the Prometheus grammar
                        (counters end ``_total``); handles bind at
                        construction, never in tick scope; label values
                        come from the repo-wide vocabulary (a singleton
                        value one edit away from an established one is
                        the ``kind="sesion"`` typo class).
ownership-pair/-deferred
                        a class that ``share()``s or ``cow()``s pages
                        must also release them somewhere
                        (``drop_page``/``release``/``free_request``);
                        engine/session-layer tier movers run inside a
                        ``store.deferred()`` episode so eviction storms
                        stay batched.
donated-reread / prefill-bucket
                        a buffer donated to a jitted call is reassigned
                        in the same function after the dispatch; every
                        prefill batch comes from ``_pad_prompt`` (the
                        bucketing choke point), never a raw dict.

Device-value tracking is an intra-function taint walk: values produced
by ``jnp.*``/``jax.*`` calls (or jitted attributes, or device-resident
``self`` attributes discovered by a per-class fixpoint) are device;
``jax.device_get`` and the host casts launder back to host.  The walk is
deliberately shallow -- no inter-procedural taint -- so its false
positives stay explainable and its misses are covered by the runtime
transfer guard (repro.analysis.runtime).
"""
from __future__ import annotations

import ast
import pathlib
from typing import Optional

from repro.analysis.callgraph import SymbolIndex
from repro.analysis.findings import Finding, Pragmas

# the decode-loop roots: everything reachable from these is tick scope
ROOTS = (("PagedEngine", "step"), ("Engine", "step"))

ALL_RULES = ("hot-sync", "hot-branch", "metrics-name", "metrics-bind",
             "metrics-label", "ownership-pair", "ownership-deferred",
             "donated-reread", "prefill-bucket")

# calls that produce HOST values (cut the taint walk; some are also the
# banned casts when fed a device value)
_HOST_PRODUCERS = {
    "jax.device_get", "int", "float", "bool", "str", "len", "range",
    "isinstance", "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "time.time", "time.perf_counter", "jnp.dtype", "jnp.shape",
}
# array METADATA reads are host values even on a device array: shapes
# and dtypes never live on the accelerator
_HOST_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize", "nbytes"}
_HOST_CASTS = {"int", "float", "bool"}
_NP_READS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}

# device-resident attribute seeds that the per-class assignment fixpoint
# cannot derive (built through helpers, e.g. the tier store's pools
# tuple): reads of ``self.<name>`` count as device values
DEVICE_ATTR_SEEDS = {"pools"}

_METRIC_FACTORIES = {"counter", "gauge", "histogram"}
_METRIC_READS = {"get_value", "families"}
_LABEL_KEYS = {"kind", "cls", "to", "tier", "task", "site", "reason"}
_MOVERS = {"demote_to_warm", "demote_to_cold", "promote_to_hot",
           "promote_to_warm", "copy_hot"}
_ACQUIRES = {"share", "cow"}
_RELEASES = {"drop_page", "release", "free_request"}
# the mover-episode rule applies where eviction storms originate
_DEFERRED_SCOPES = ("serving/", "sessions/")


def _dotted(node) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _iter_own_nodes(fn: ast.AST):
    """Walk a function body, excluding nested def/class/lambda bodies
    (jit closures are traced code, not host code)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


# -- taint --------------------------------------------------------------------

class _Taint:
    """Intra-function device-value tracking."""

    def __init__(self, device_attrs: set, jit_attrs: set):
        self.device_attrs = device_attrs
        self.jit_attrs = jit_attrs
        self.names: set = set()

    def tainted(self, node) -> bool:
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in _HOST_ATTRS:
                return False
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in self.device_attrs):
                return True
            return self.tainted(node.value)
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d in _HOST_PRODUCERS:
                return False
            if d is not None:
                root = d.split(".", 1)[0]
                if root in ("jnp", "jax"):
                    return True
                if d.startswith("self.") and d[5:] in self.jit_attrs:
                    return True
            return (any(self.tainted(a) for a in node.args)
                    or any(self.tainted(k.value) for k in node.keywords))
        return any(self.tainted(c) for c in ast.iter_child_nodes(node))

    def assign(self, target, is_device: bool):
        if isinstance(target, ast.Name):
            (self.names.add if is_device
             else self.names.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.assign(elt, is_device)
        # self.X targets are handled by the class-level fixpoint


def _class_device_attrs(methods, jit_attrs: set) -> set:
    """Per-class fixpoint: attributes ever assigned a device value in
    any method become device attributes everywhere in the class."""
    attrs = set(DEVICE_ATTR_SEEDS)
    for _ in range(4):                       # tiny lattice; converges fast
        grew = False
        for fn in methods:
            taint = _Taint(attrs, jit_attrs)
            for node in _walk_statements(fn):
                _simulate_assign(node, taint)
                if isinstance(node, ast.Assign):
                    dev = taint.tainted(node.value)
                    if not dev:
                        continue
                    for tgt in node.targets:
                        for leaf in ([tgt] if not isinstance(
                                tgt, (ast.Tuple, ast.List)) else tgt.elts):
                            if (isinstance(leaf, ast.Attribute)
                                    and isinstance(leaf.value, ast.Name)
                                    and leaf.value.id == "self"
                                    and leaf.attr not in attrs):
                                attrs.add(leaf.attr)
                                grew = True
        if not grew:
            break
    return attrs


def _walk_statements(fn):
    """Statements of a function in source order (nested defs excluded),
    with loop bodies visited twice so loop-carried taint propagates."""
    def emit(body):
        out = []
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            out.append(stmt)
            for field in ("body", "orelse", "finalbody"):
                inner = getattr(stmt, field, None)
                if inner:
                    sub = emit(inner)
                    out.extend(sub)
                    if isinstance(stmt, (ast.For, ast.While)):
                        out.extend(sub)      # second pass: loop carry
            for h in getattr(stmt, "handlers", ()) or ():
                out.extend(emit(h.body))
        return out
    return emit(fn.body)


def _simulate_assign(node, taint: _Taint):
    """Update the taint set for one statement (no findings)."""
    if isinstance(node, ast.Assign):
        dev = taint.tainted(node.value)
        for tgt in node.targets:
            taint.assign(tgt, dev)
    elif isinstance(node, ast.AnnAssign) and node.value is not None:
        taint.assign(node.target, taint.tainted(node.value))
    elif isinstance(node, ast.AugAssign):
        if taint.tainted(node.value):
            taint.assign(node.target, True)
    elif isinstance(node, ast.For):
        taint.assign(node.target, taint.tainted(node.iter))
    elif isinstance(node, ast.With):
        for item in node.items:
            if item.optional_vars is not None:
                taint.assign(item.optional_vars,
                             taint.tainted(item.context_expr))


# -- per-module scan ----------------------------------------------------------

class Module:
    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source)
        self.pragmas = Pragmas(source, relpath)


def _stmt_exprs(stmt):
    """The expressions belonging to ONE statement (headers of compound
    statements; nested statement bodies are visited as their own
    statements, so walking them here would double-report)."""
    if isinstance(stmt, ast.With):
        for i in stmt.items:
            yield i.context_expr
        return
    for c in ast.iter_child_nodes(stmt):
        if isinstance(c, ast.expr):
            yield c


def _expr_calls(stmt):
    for root in _stmt_exprs(stmt):
        for sub in ast.walk(root):
            if isinstance(sub, ast.Call):
                yield sub


def _class_jit_attrs(cls_node) -> dict:
    """{attr: donate_argnums tuple} for ``self.X = jax.jit(...)``
    assignments anywhere in the class (module-level jits resolve through
    the same shapes with an empty class)."""
    out = {}
    for node in ast.walk(cls_node):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and _dotted(call.func) == "jax.jit"):
            continue
        donated = ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                try:
                    donated = tuple(ast.literal_eval(kw.value))
                except (ValueError, TypeError):
                    donated = ()
        out[tgt.attr] = donated
    return out


def _check_function(mod: Module, fn, qualname: str, device_attrs: set,
                    jit_attrs: dict, in_tick_scope: bool,
                    findings: list):
    """Hot-sync / hot-branch / metrics-bind / donated-reread /
    prefill-bucket over one function body."""
    taint = _Taint(device_attrs, set(jit_attrs))
    assigns = [n for n in _walk_statements(fn) if isinstance(n, ast.Assign)]

    def emit(rule, node, msg):
        findings.append(Finding(rule, mod.relpath, node.lineno,
                                qualname, msg))

    for stmt in _walk_statements(fn):
        _simulate_assign(stmt, taint)
        if in_tick_scope and isinstance(stmt, (ast.If, ast.While)):
            if taint.tainted(stmt.test):
                emit("hot-branch", stmt,
                     "Python control flow on a device value forces a "
                     "blocking d2h read in the decode tick")
        for call in _expr_calls(stmt):
            d = _dotted(call.func)
            attr = (call.func.attr
                    if isinstance(call.func, ast.Attribute) else None)
            if in_tick_scope:
                if d == "jax.device_get":
                    emit("hot-sync", call,
                         "jax.device_get in tick scope (host sync)")
                elif attr == "block_until_ready":
                    emit("hot-sync", call,
                         "block_until_ready in tick scope (host sync)")
                elif attr == "item" and not call.args:
                    emit("hot-sync", call,
                         ".item() in tick scope (host sync)")
                elif (d in _HOST_CASTS
                        and any(taint.tainted(a) for a in call.args)):
                    emit("hot-sync", call,
                         f"{d}() of a device value in tick scope "
                         f"(host sync)")
                elif (d in _NP_READS
                        and any(taint.tainted(a) for a in call.args)):
                    emit("hot-sync", call,
                         f"{d}() of a device value in tick scope "
                         f"(d2h read the transfer guard cannot see "
                         f"on CPU)")
                elif attr in (_METRIC_FACTORIES | _METRIC_READS):
                    emit("metrics-bind", call,
                         f".{attr}() in tick scope: bind metric handles "
                         f"in __init__, not per tick")
            # donated-reread: the donated operand must be reassigned
            # after the dispatch, in the same function
            if (d is not None and d.startswith("self.")
                    and d[5:] in jit_attrs and jit_attrs[d[5:]]):
                for pos in jit_attrs[d[5:]]:
                    if pos >= len(call.args):
                        continue
                    donated = _dotted(call.args[pos])
                    if donated is None:
                        continue
                    ok = any(
                        a.lineno >= call.lineno
                        and any(_dotted(t) == donated for t in a.targets)
                        for a in assigns)
                    if not ok:
                        emit("donated-reread", call,
                             f"donated buffer {donated} is not "
                             f"reassigned after the jitted dispatch "
                             f"(reading it is use-after-donate)")
            # prefill-bucket: the batch operand of self._prefill must
            # come from _pad_prompt (the bucketing choke point)
            if d == "self._prefill" and len(call.args) >= 2:
                batch = call.args[1]
                ok = False
                bd = _dotted(batch)
                if (isinstance(batch, ast.Call)
                        and _dotted(batch.func) == "self._pad_prompt"):
                    ok = True
                elif bd is not None:
                    for a in assigns:
                        if (a.lineno <= call.lineno
                                and any(_dotted(t) == bd
                                        for t in a.targets)
                                and isinstance(a.value, ast.Call)
                                and _dotted(a.value.func)
                                == "self._pad_prompt"):
                            ok = True
                if not ok:
                    emit("prefill-bucket", call,
                         "prefill batch does not come from _pad_prompt: "
                         "unbucketed shapes recompile per prompt length")


def _check_metrics_names(mod: Module, findings: list):
    import re
    name_re = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        attr = (node.func.attr
                if isinstance(node.func, ast.Attribute) else None)
        if attr not in _METRIC_FACTORIES or not node.args:
            continue
        first = node.args[0]
        if not (isinstance(first, ast.Constant)
                and isinstance(first.value, str)):
            continue
        name = first.value
        qual = "<module>"
        if not name_re.match(name):
            findings.append(Finding(
                "metrics-name", mod.relpath, node.lineno, qual,
                f"metric name {name!r} violates the Prometheus grammar"))
        elif attr == "counter" and not name.endswith("_total"):
            findings.append(Finding(
                "metrics-name", mod.relpath, node.lineno, qual,
                f"counter {name!r} must end in _total"))


def _edit_distance(a: str, b: str) -> int:
    if abs(len(a) - len(b)) > 1:
        return 2                             # only 0/1 matter here
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        for j, cb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1,
                           prev[j - 1] + (ca != cb)))
        prev = cur
    return prev[-1]


def _check_label_vocab(modules: list, findings: list):
    """Repo-wide closed label vocabulary: a literal label value used
    exactly once, one edit away from a value used >= 2 times, is a typo."""
    sites: list = []                         # (key, value, mod, node)
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if (kw.arg in _LABEL_KEYS
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, str)):
                    sites.append((kw.arg, kw.value.value, mod, node))
    counts: dict = {}
    for key, val, _, _ in sites:
        counts[(key, val)] = counts.get((key, val), 0) + 1
    established = {(k, v) for (k, v), n in counts.items() if n >= 2}
    for key, val, mod, node in sites:
        if counts[(key, val)] != 1:
            continue
        near = [v for (k, v) in established
                if k == key and _edit_distance(val, v) == 1]
        if near:
            findings.append(Finding(
                "metrics-label", mod.relpath, node.lineno, "<module>",
                f"label {key}={val!r} appears once and is one edit from "
                f"established {key}={near[0]!r} -- typo?"))


def _check_ownership_pair(mod: Module, findings: list):
    """A class that share()/cow()s pages must release them somewhere."""
    for node in mod.tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        defined = {i.name for i in node.body
                   if isinstance(i, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))}
        if _ACQUIRES & defined:
            continue                         # the pool itself / a stub
        acquires, releases = [], False
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and isinstance(sub.func,
                                                        ast.Attribute):
                if sub.func.attr in _ACQUIRES:
                    acquires.append(sub)
                elif sub.func.attr in _RELEASES:
                    releases = True
        if acquires and not releases:
            first = acquires[0]
            findings.append(Finding(
                "ownership-pair", mod.relpath, first.lineno, node.name,
                f"class takes page references ({first.func.attr}) but "
                f"never releases them (no drop_page/release/"
                f"free_request call)"))


def _check_deferred(mod: Module, findings: list):
    """Tier movers in engine/session code must run inside a
    ``store.deferred()`` episode (batched-dispatch discipline)."""
    if not any(s in mod.relpath for s in _DEFERRED_SCOPES):
        return

    def walk(node, qual, in_deferred):
        for child in ast.iter_child_nodes(node):
            q, deferred = qual, in_deferred
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = (f"{qual}.{child.name}" if qual != "<module>"
                     else child.name)
                deferred = False             # episodes do not cross defs
            elif isinstance(child, ast.ClassDef):
                q = child.name
            elif isinstance(child, ast.With):
                if any(isinstance(i.context_expr, ast.Call)
                       and isinstance(i.context_expr.func, ast.Attribute)
                       and i.context_expr.func.attr == "deferred"
                       for i in child.items):
                    deferred = True
            elif (isinstance(child, ast.Call)
                    and isinstance(child.func, ast.Attribute)
                    and child.func.attr in _MOVERS
                    and not in_deferred):
                findings.append(Finding(
                    "ownership-deferred", mod.relpath, child.lineno, qual,
                    f".{child.func.attr}() outside a store.deferred() "
                    f"episode: single-page mover dispatches serialize "
                    f"eviction storms"))
            walk(child, q, deferred)

    walk(mod.tree, "<module>", False)


# -- driver -------------------------------------------------------------------

def _collect_files(paths) -> list:
    files = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def run_checks(paths, root=None, rules=None, roots=ROOTS) -> list:
    """Run the rule catalog over ``paths``; returns unsuppressed
    findings sorted by location.  ``root`` anchors the repo-relative
    paths used in fingerprints (defaults to the common parent)."""
    rules = set(rules if rules is not None else ALL_RULES)
    files = _collect_files(paths)
    root = pathlib.Path(root) if root is not None else None
    modules, findings = [], []
    for f in files:
        rel = (f.relative_to(root) if root and f.is_relative_to(root)
               else f).as_posix()
        try:
            modules.append(Module(rel, f.read_text()))
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding("parse", rel,
                                    getattr(e, "lineno", 1) or 1,
                                    "<module>", f"unparseable: {e.msg}"))

    index = SymbolIndex()
    for mod in modules:
        index.add_module(mod.relpath, mod.tree)
    tick_scope = index.reachable(roots)

    for mod in modules:
        if rules & {"metrics-name"}:
            _check_metrics_names(mod, findings)
        if rules & {"ownership-pair"}:
            _check_ownership_pair(mod, findings)
        if rules & {"ownership-deferred"}:
            _check_deferred(mod, findings)
        # per-function rules need class context (jit attrs / device attrs)
        tops = [(n, None) for n in mod.tree.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                tops.extend((i, node) for i in node.body
                            if isinstance(i, (ast.FunctionDef,
                                              ast.AsyncFunctionDef)))
        by_class: dict = {}
        for fn, cls in tops:
            by_class.setdefault(cls.name if cls else None,
                                []).append(fn)
        jit_by_class = {}
        dev_by_class = {}
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                jit = _class_jit_attrs(node)
                jit_by_class[node.name] = jit
                dev_by_class[node.name] = _class_device_attrs(
                    by_class.get(node.name, []), set(jit))
        for fn, cls in tops:
            cname = cls.name if cls else None
            qual = f"{cname}.{fn.name}" if cname else fn.name
            in_scope = f"{mod.relpath}::{qual}" in tick_scope
            _check_function(
                mod, fn, qual,
                dev_by_class.get(cname, set(DEVICE_ATTR_SEEDS)),
                jit_by_class.get(cname, {}), in_scope, findings)

    if rules & {"metrics-label"}:
        _check_label_vocab(modules, findings)

    # pragma suppression + reasonless-pragma findings
    pragmas = {m.relpath: m.pragmas for m in modules}
    kept = []
    for f in findings:
        if f.rule not in rules and f.rule != "parse":
            continue
        p = pragmas.get(f.path)
        if p is not None and p.covers(f.rule, f.line):
            continue
        kept.append(f)
    for m in modules:
        kept.extend(m.pragmas.reasonless_findings())
    # the taint walk visits loop bodies twice (loop-carried taint); the
    # second pass must not double-report
    kept = sorted(set(kept), key=lambda f: (f.path, f.line, f.rule))
    return kept

"""repro.analysis -- the hot-path sanitizer (DESIGN.md 16).

Static half: an AST linter (``rules.run_checks``) enforcing the decode
loop's invariants -- hot-path purity, metrics discipline, page-ownership
protocol, jit-boundary hygiene -- reachability-scoped to the engine
``step`` roots, with ``# sync-ok:``/``# lint-ok():`` pragmas for the
sanctioned exemptions and a grandfather baseline (``baseline``).  Run it
via ``python tools/check.py``.

Runtime half (``runtime``): ``jax.transfer_guard`` around the jitted
tick dispatch behind ``ObsSpec.strict_transfers``, and the retrace
sentinel asserting the prefill compile-count bound per scenario.

This package imports only the stdlib (the CI linter job needs no jax);
``runtime`` imports jax lazily, and only when a guard is enabled.
"""
from repro.analysis.baseline import (load_baseline, new_findings,
                                     save_baseline)
from repro.analysis.findings import (Finding, PRAGMA_NO_REASON, Pragmas,
                                     SYNC_RULES)
from repro.analysis.rules import ALL_RULES, ROOTS, run_checks

__all__ = [
    "ALL_RULES", "Finding", "PRAGMA_NO_REASON", "Pragmas", "ROOTS",
    "SYNC_RULES", "load_baseline", "new_findings", "run_checks",
    "save_baseline",
]

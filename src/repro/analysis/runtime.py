"""Runtime sentinels for the hazards the AST cannot see (DESIGN.md 16).

Two guards, both fence-free when disabled (the NULL_REGISTRY pattern:
disabled mode costs one attribute read and a no-op context manager, no
jax import, no device traffic):

``tick_guard``      a context-manager factory wrapping the jitted tick
                    dispatch in ``jax.transfer_guard("disallow")``:
                    any IMPLICIT host<->device transfer inside the
                    dispatch (a host mirror leaked into the jit args, a
                    Python scalar re-staged per tick) raises instead of
                    silently serializing.  Explicit moves
                    (``jax.device_get``, ``jax.device_put``) stay legal
                    -- the lagged harvest is sanctioned.  Note the CPU
                    backend's d2h reads (``np.asarray`` of a committed
                    array) are zero-copy and invisible to the guard;
                    the AST hot-sync rule covers that gap.
``RetraceSentinel`` / ``assert_compile_bound``
                    the compile-count assertion behind the PR 5 bucket
                    ladder: >= 12 distinct prompt lengths must compile
                    <= n_prompt_buckets prefill variants.  serving_micro
                    checks it per scenario so a quiet bucketing
                    regression fails CI, not a later bisect.
"""
from __future__ import annotations

import dataclasses


class _NullCtx:
    """Shared no-op context manager: the disabled-guard hot path."""

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


def tick_guard(strict: bool):
    """A zero-arg context factory for the jitted tick dispatch.

    ``strict=False`` returns the shared no-op (no jax import, nothing
    on the hot path); ``strict=True`` returns a factory opening
    ``jax.transfer_guard("disallow")`` around each dispatch.  Callers
    must stage every per-tick jit input as a committed device value
    BEFORE opening the guard -- implicit h2d of a host mirror inside it
    raises, which is exactly the invariant being enforced.
    """
    if not strict:
        return lambda: _NULL_CTX
    import jax
    return lambda: jax.transfer_guard("disallow")


class RetraceError(AssertionError):
    """A scenario compiled more prefill variants than the bucket ladder
    allows -- the pre-PR one-program-per-prompt-length regression."""


def assert_compile_bound(scenario: str, compiles: int, bound: int) -> None:
    if compiles > bound:
        raise RetraceError(
            f"{scenario}: {compiles} prefill compiles exceeds the "
            f"{bound}-bucket bound (prompt bucketing regressed; see "
            f"DESIGN.md 12/16)")


@dataclasses.dataclass
class RetraceSentinel:
    """Compile-count watchdog bound to one engine: ``check()`` after a
    scenario asserts the bucket-ladder bound still holds."""
    scenario: str
    bound: int

    def check(self, engine) -> int:
        compiles = engine.prefill_compiles()
        assert_compile_bound(self.scenario, compiles, self.bound)
        return compiles

"""Findings and pragmas for the hot-path sanitizer (DESIGN.md 16).

A ``Finding`` is one rule violation at one source location.  Its
``fingerprint`` deliberately omits the line number: baselines must
survive unrelated edits above a grandfathered site, so identity is
(rule, file, enclosing def, message) -- stable until the offending code
itself moves files or changes meaning.

Suppression pragmas are comments on the offending line or the line
above (the comment marker is elided here so the sanitizer does not read
its own documentation as pragmas):

    ``sync-ok: <reason>``           exempts a sanctioned host sync
                                    (hot-sync / hot-branch rules only)
    ``lint-ok(<rule>): <reason>``   exempts one named rule
    ``lint-ok: <reason>``           exempts any rule on that line

The reason is mandatory: a pragma with an empty reason both fails to
suppress and raises its own ``pragma-no-reason`` finding, which is never
baselineable -- every exemption stays self-documenting.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# the rules a bare sync-ok pragma covers: sanctioned host syncs
SYNC_RULES = frozenset({"hot-sync", "hot-branch"})
PRAGMA_NO_REASON = "pragma-no-reason"

_PRAGMA_RE = re.compile(
    r"#\s*(?P<kind>sync-ok|lint-ok)"
    r"(?:\(\s*(?P<rule>[a-z0-9_-]+)\s*\))?"
    r"\s*:\s*(?P<reason>.*?)\s*$")
# looser net: a pragma-shaped comment that the strict form rejects
# (missing colon / reason) must fail loudly, not silently not-suppress
_PRAGMA_ANY_RE = re.compile(r"#\s*(sync-ok|lint-ok)\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str          # repo-relative posix path
    line: int
    qualname: str      # enclosing Class.method / function / <module>
    message: str

    def fingerprint(self) -> str:
        return "|".join((self.rule, self.path, self.qualname, self.message))

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"{self.qualname}: {self.message}")


@dataclasses.dataclass(frozen=True)
class Pragma:
    kind: str                  # "sync-ok" | "lint-ok"
    rule: Optional[str]        # the named rule filter; None = any rule
    reason: str
    line: int


class Pragmas:
    """Per-file pragma table: which (rule, line) pairs are exempted."""

    def __init__(self, source: str, path: str):
        self.path = path
        self._by_line: dict[int, Pragma] = {}
        self.malformed: list[int] = []     # pragma-shaped, reason missing
        for i, text in enumerate(source.splitlines(), start=1):
            m = _PRAGMA_RE.search(text)
            if m:
                p = Pragma(m.group("kind"), m.group("rule"),
                           m.group("reason"), i)
                if p.reason:
                    self._by_line[i] = p
                else:
                    self.malformed.append(i)
            elif _PRAGMA_ANY_RE.search(text):
                self.malformed.append(i)

    def covers(self, rule: str, line: int) -> Optional[Pragma]:
        """The pragma exempting ``rule`` at ``line`` (same line or the
        line above), if any."""
        for ln in (line, line - 1):
            p = self._by_line.get(ln)
            if p is None:
                continue
            if p.kind == "sync-ok" and rule in SYNC_RULES:
                return p
            if p.kind == "lint-ok" and (p.rule is None or p.rule == rule):
                return p
        return None

    def reasonless_findings(self) -> list[Finding]:
        return [Finding(PRAGMA_NO_REASON, self.path, ln, "<module>",
                        "suppression pragma without a reason (write "
                        "'sync-ok: <why>' or 'lint-ok(<rule>): <why>' "
                        "after the comment marker)")
                for ln in self.malformed]

"""Train-step factories: plain (GSPMD collectives) and CABA-compressed.

``make_train_step`` builds the jit-able step for one model:
  * microbatched gradient accumulation (lax.scan over microbatches, fp32
    accumulators) -- also the compute/comm overlap vehicle: XLA's
    latency-hiding scheduler overlaps each microbatch's reduce-scatter with
    the next microbatch's backward,
  * mixed precision: bf16 params/activations, fp32 loss/optimizer math,
  * optional CABA sites: compressed cross-pod gradient collective
    (grad_compress.py) and int8 optimizer state (optimizer.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.assist import AssistSpec
from repro.training import optimizer as opt_mod
from repro.training import grad_compress as gc_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = opt_mod.OptConfig()
    grad_accum: int = 1
    grad_compression: Optional[gc_mod.GradCompressionConfig] = None
    # declarative assist sites (repro.assist); folded into the concrete
    # knobs by resolved() -- explicit grad_compression/opt settings win
    assist: Optional[AssistSpec] = None

    def resolved(self) -> "TrainConfig":
        """Fold the assist spec into the concrete training knobs."""
        if self.assist is None:
            return self
        spec = self.assist
        gc = self.grad_compression
        if gc is None and spec.grads != "raw":
            gc = gc_mod.GradCompressionConfig(axis=spec.grad_axis,
                                              kind=spec.grads)
        opt = self.opt
        if opt.state_compression is None and spec.opt_state != "raw":
            opt = dataclasses.replace(opt, state_compression=spec.opt_state)
        return dataclasses.replace(self, opt=opt, grad_compression=gc)


def _split_microbatches(batch, n: int):
    """[B, ...] -> [n, B/n, ...] per leaf."""
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, tcfg: TrainConfig, mesh=None):
    """Returns step(train_state, batch) -> (train_state, metrics).

    train_state: dict(params, opt, residual?) -- a plain pytree so it
    checkpoints/reshards trivially.
    """
    tcfg = tcfg.resolved()
    loss_fn = model.loss

    if tcfg.grad_compression is not None:
        assert mesh is not None, "compressed grads need the mesh"
        vag = gc_mod.make_compressed_value_and_grad(
            loss_fn, mesh, tcfg.grad_compression)

    def grads_of(params, batch, residual):
        if tcfg.grad_compression is not None:
            loss, metrics, grads, residual = vag(params, batch, residual)
            return loss, metrics, grads, residual
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads, residual

    def step(train_state, batch):
        params = train_state["params"]
        residual = train_state.get("residual")
        if tcfg.grad_accum == 1:
            loss, metrics, grads, residual = grads_of(params, batch, residual)
        else:
            micro = _split_microbatches(batch, tcfg.grad_accum)

            def acc_step(carry, mb):
                g_acc, res = carry
                l, m, g, res = grads_of(params, mb, res)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, res), (l, m)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g_sum, residual), (losses, metricses) = jax.lax.scan(
                acc_step, (g0, residual), micro)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, g_sum)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)

        new_params, new_opt, stats = opt_mod.adamw_update(
            grads, train_state["opt"], params, tcfg.opt)
        out_state = {"params": new_params, "opt": new_opt}
        if residual is not None:
            out_state["residual"] = residual
        return out_state, {"loss": loss, **metrics, **stats}

    return step


def init_train_state(model, tcfg: TrainConfig, rng, mesh=None):
    tcfg = tcfg.resolved()
    params = model.init(rng)
    state = {"params": params, "opt": opt_mod.init_opt_state(params, tcfg.opt)}
    if tcfg.grad_compression is not None:
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        axis = tcfg.grad_compression.axis
        size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
        state["residual"] = gc_mod.init_residual(n, size)
    return state


def train_state_specs(model, tcfg: TrainConfig, mesh=None):
    """ShapeDtypeStructs of the train state (dry-run; no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(model, tcfg, jax.random.PRNGKey(0), mesh))

"""Compressed gradient collectives: CABA's interconnect-compression site.

The paper compresses crossbar (interconnect) traffic by running compression
subroutines on the cores (5, Fig. 9: CABA-BDI beats memory-only compression
on interconnect-bound apps).  The training-time analogue is the gradient
reduction across the DP axes -- on a multi-pod machine the ``pod`` axis is
DCN (slow links), exactly the bandwidth-starved hop.

Scheme (DESIGN.md 6): the REDUCE-SCATTER leg stays full precision (summing
quantized values would compound error); the ALL-GATHER leg moves fixed-rate
8-bit payload + per-block scales, with per-shard ERROR FEEDBACK so each
step's quantization error is re-injected next step instead of lost.

    bytes(all_reduce)      = 2 (g-1)/g N
    bytes(rs + q8 gather)  =   (g-1)/g N (1 + 1/4)      ->  ~37% saved
                                (+2 B per 256-value block of scales)

Structure: the loss/grad runs OUTSIDE the manual region, vmapped over an
explicit per-DP-shard lane dimension (``spmd_axis_name`` threads the DP
axis into the model's internal sharding constraints, so FSDP/TP inside the
model is untouched); only the scan-free reduce-scatter + quantize body runs
in a shard_map that is MANUAL over the DP axis.  Keeping control flow
(the scanned layer stack) out of the partial-manual region matters: XLA's
SPMD partitioner cannot partition a while loop inside a manual subgroup
(hlo_sharding_util ``IsManualSubgroup`` check failure).  The quantized
shard leaves the manual region pod-sharded; a sharding constraint outside
forces the all-gather to happen ON THE INT8 PAYLOAD (the compressed leg),
after which dequantization is a local VPU op.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.assist.schemes.quant import BLOCK_VALUES as BLOCK
# quantization block (values) shared with the assist quant scheme, so the
# grad site's fixed-rate payload matches the registered compress task


def flatten_tree(tree):
    """pytree -> flat fp32 [N] (gradient bucketing, like NCCL fusion)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def unflatten_like(tree, vec):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _quant_blocks(x, kind: str):
    """f32[M] (M % BLOCK == 0) -> (payload [M], scale f32[M/BLOCK])."""
    b = x.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(b), axis=-1, keepdims=True)
    if kind == "int8":
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    elif kind == "fp8":
        scale = jnp.where(absmax > 0, absmax / 448.0, 1.0)
        q = (b / scale).astype(jnp.float8_e4m3fn)
    else:
        raise ValueError(kind)
    return q.reshape(-1), scale[:, 0]


def _dequant_blocks(q, scale):
    return (q.astype(jnp.float32).reshape(-1, BLOCK)
            * scale[:, None]).reshape(-1)


def padded_len(n: int, axis_size: int) -> int:
    return n + ((-n) % (axis_size * BLOCK))


def init_residual(n_params: int, axis_size: int):
    """Global error-feedback carry (pod-sharded by shard_map at use)."""
    return jnp.zeros((padded_len(n_params, axis_size),), jnp.float32)


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    axis: str = "pod"          # mesh axis to compress across (DCN hop)
    kind: str = "fp8"          # fp8 | int8
    error_feedback: bool = True

    def bytes_saved_fraction(self) -> float:
        """Fraction of all-reduce bytes saved (napkin, excl. scales)."""
        return 1.0 - (1 + 0.25) / 2.0


def make_compressed_value_and_grad(loss_fn, mesh, cfg: GradCompressionConfig):
    """value_and_grad whose DP reduction over ``cfg.axis`` is RS(fp32) +
    all-gather(8-bit, error feedback).

    Returns fn(params, batch, residual) ->
        (loss, metrics, grads, new_residual)
    with grads replicated over the axis and residual the per-shard carry
    (allocate with :func:`init_residual`).
    """
    g = dict(zip(mesh.axis_names, mesh.devices.shape))[cfg.axis]

    def reduce_quant(lane_flat, residual):
        # lane_flat: this shard's lane [1, Npad]; residual: [Npad/g].
        # Scan-free body -> safe inside a partial-manual (auto-axes) region.
        shard = jax.lax.psum_scatter(lane_flat.reshape(g, -1), cfg.axis,
                                     scatter_dimension=0, tiled=False)
        shard = shard / g                              # mean over DP shards
        if cfg.error_feedback:
            shard = shard + residual
        q, scale = _quant_blocks(shard, cfg.kind)
        new_res = (shard - _dequant_blocks(q, scale)) if cfg.error_feedback \
            else jnp.zeros_like(shard)
        return q, scale, new_res

    from repro.launch.sharding import manual_shard_map
    # manual over the DP axis only; remaining mesh axes stay auto (GSPMD)
    sharded = manual_shard_map(
        reduce_quant, mesh, {cfg.axis},
        (P(cfg.axis), P(cfg.axis)),
        (P(cfg.axis), P(cfg.axis), P(cfg.axis)))

    rep = NamedSharding(mesh, P())
    lane_sh = NamedSharding(mesh, P(cfg.axis))

    def fn(params, batch, residual):
        # One gradient lane per DP shard: vmap over an explicit leading axis
        # of size g; spmd_axis_name threads cfg.axis into the model's
        # internal sharding constraints, so the lane dim partitions over the
        # DP axis and FSDP/TP constraints inside loss_fn keep working.
        batch_g = jax.tree.map(
            lambda b: b.reshape((g, b.shape[0] // g) + b.shape[1:]), batch)

        def lane(b):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
            return loss, metrics, flatten_tree(grads)

        loss_g, metrics_g, flat_g = jax.vmap(
            lane, spmd_axis_name=cfg.axis)(batch_g)
        loss = jnp.mean(loss_g)            # equal lanes: mean == global mean
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_g)
        n = flat_g.shape[1]
        pad = padded_len(n, g) - n
        xp = jnp.pad(flat_g, ((0, 0), (0, pad)))
        xp = jax.lax.with_sharding_constraint(xp, lane_sh)
        q, scale, new_res = sharded(xp, residual)
        # compressed all-gather leg: constrain the INT8 payload replicated,
        # so GSPMD's all-gather moves 8-bit bytes; dequant is then local.
        q = jax.lax.with_sharding_constraint(q, rep)
        scale = jax.lax.with_sharding_constraint(scale, rep)
        full = _dequant_blocks(q, scale)
        grads = unflatten_like(params, full[:n])
        return loss, metrics, grads, new_res

    return fn

"""Compressed gradient collectives: CABA's interconnect-compression site.

The paper compresses crossbar (interconnect) traffic by running compression
subroutines on the cores (5, Fig. 9: CABA-BDI beats memory-only compression
on interconnect-bound apps).  The training-time analogue is the gradient
reduction across the DP axes -- on a multi-pod machine the ``pod`` axis is
DCN (slow links), exactly the bandwidth-starved hop.

Scheme (DESIGN.md 6): the REDUCE-SCATTER leg stays full precision (summing
quantized values would compound error); the ALL-GATHER leg moves fixed-rate
8-bit payload + per-block scales, with per-shard ERROR FEEDBACK so each
step's quantization error is re-injected next step instead of lost.

    bytes(all_reduce)      = 2 (g-1)/g N
    bytes(rs + q8 gather)  =   (g-1)/g N (1 + 1/4)      ->  ~37% saved
                                (+2 B per 256-value block of scales)

Structure: the loss/grad + reduce-scatter + quantize run in a shard_map
that is MANUAL over the DP axis only (other mesh axes stay under GSPMD, so
FSDP/TP inside the model is untouched).  The quantized shard leaves the
manual region pod-sharded; a sharding constraint outside forces the
all-gather to happen ON THE INT8 PAYLOAD (the compressed leg), after which
dequantization is a local VPU op.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

BLOCK = 256  # quantization block (values), matches core/schemes/quant.py


def flatten_tree(tree):
    """pytree -> flat fp32 [N] (gradient bucketing, like NCCL fusion)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def unflatten_like(tree, vec):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _quant_blocks(x, kind: str):
    """f32[M] (M % BLOCK == 0) -> (payload [M], scale f32[M/BLOCK])."""
    b = x.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(b), axis=-1, keepdims=True)
    if kind == "int8":
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    elif kind == "fp8":
        scale = jnp.where(absmax > 0, absmax / 448.0, 1.0)
        q = (b / scale).astype(jnp.float8_e4m3fn)
    else:
        raise ValueError(kind)
    return q.reshape(-1), scale[:, 0]


def _dequant_blocks(q, scale):
    return (q.astype(jnp.float32).reshape(-1, BLOCK)
            * scale[:, None]).reshape(-1)


def padded_len(n: int, axis_size: int) -> int:
    return n + ((-n) % (axis_size * BLOCK))


def init_residual(n_params: int, axis_size: int):
    """Global error-feedback carry (pod-sharded by shard_map at use)."""
    return jnp.zeros((padded_len(n_params, axis_size),), jnp.float32)


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    axis: str = "pod"          # mesh axis to compress across (DCN hop)
    kind: str = "fp8"          # fp8 | int8
    error_feedback: bool = True

    def bytes_saved_fraction(self) -> float:
        """Fraction of all-reduce bytes saved (napkin, excl. scales)."""
        return 1.0 - (1 + 0.25) / 2.0


def make_compressed_value_and_grad(loss_fn, mesh, cfg: GradCompressionConfig):
    """value_and_grad whose DP reduction over ``cfg.axis`` is RS(fp32) +
    all-gather(8-bit, error feedback).

    Returns fn(params, batch, residual) ->
        (loss, metrics, grads, new_residual)
    with grads replicated over the axis and residual the per-shard carry
    (allocate with :func:`init_residual`).
    """
    g = dict(zip(mesh.axis_names, mesh.devices.shape))[cfg.axis]

    def per_shard(params, batch, residual):
        # pcast params to axis-VARYING before differentiating: otherwise the
        # VMA transpose rule auto-psums the cotangents over the axis (an
        # uncompressed all-reduce -- exactly what this path replaces).
        params = jax.tree.map(
            lambda p: jax.lax.pcast(p, (cfg.axis,), to="varying"), params)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        flat = flatten_tree(grads)
        n = flat.shape[0]
        pad = padded_len(n, g) - n
        xp = jnp.pad(flat, (0, pad))
        shard = jax.lax.psum_scatter(xp.reshape(g, -1), cfg.axis,
                                     scatter_dimension=0, tiled=False)
        shard = shard / g                              # mean over DP shards
        if cfg.error_feedback:
            shard = shard + residual
        q, scale = _quant_blocks(shard, cfg.kind)
        new_res = (shard - _dequant_blocks(q, scale)) if cfg.error_feedback \
            else jnp.zeros_like(shard)
        loss = jax.lax.pmean(loss, cfg.axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, cfg.axis), metrics)
        return loss, metrics, q, scale, new_res

    sharded = jax.shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(), P(cfg.axis), P(cfg.axis)),
        out_specs=(P(), P(), P(cfg.axis), P(cfg.axis), P(cfg.axis)),
        axis_names={cfg.axis},
    )

    rep = NamedSharding(mesh, P())

    def fn(params, batch, residual):
        loss, metrics, q, scale, new_res = sharded(params, batch, residual)
        # compressed all-gather leg: constrain the INT8 payload replicated,
        # so GSPMD's all-gather moves 8-bit bytes; dequant is then local.
        q = jax.lax.with_sharding_constraint(q, rep)
        scale = jax.lax.with_sharding_constraint(scale, rep)
        full = _dequant_blocks(q, scale)
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        grads = unflatten_like(params, full[:n])
        return loss, metrics, grads, new_res

    return fn

"""AdamW from scratch, with optional CABA-compressed optimizer state.

The optimizer-state compression site (DESIGN.md 4) stores the first/second
moments block-scaled int8 instead of fp32 -- a 4x memory-term reduction paid
for with a dequant/requant VPU pass each step (idle compute during the
memory-bound optimizer update: the paper's trade, applied to the update
step).  Error is bounded by the quant tests; training-quality impact is
benchmarked in benchmarks/fig12_algorithms.py on real tensors.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.assist.schemes import quant


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_compression: Optional[str] = None   # None | "int8" (CABA site)
    master_fp32: bool = False                 # keep fp32 master weights


def schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1.0) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _zeros_like_moment(p, compression: Optional[str], sqrt_domain=False):
    z = jnp.zeros(p.shape, jnp.float32)
    if compression:
        return quant.compress(z, compression)
    return z


def _load_moment(m, sqrt_domain: bool = False):
    if isinstance(m, quant.QuantTensor):
        v = quant.decompress(m).astype(jnp.float32)
        return jnp.square(v) if sqrt_domain else v
    return m


def _store_moment(m_new, like, compression: Optional[str],
                  sqrt_domain: bool = False):
    """``sqrt_domain``: store sqrt(v) -- block-absmax int8 crushes small
    second-moment entries to zero (Adam step explodes, observed on
    starcoder2); quantizing in the sqrt domain compresses the dynamic
    range so small entries survive (the bitsandbytes trick)."""
    if compression:
        return quant.compress(jnp.sqrt(m_new) if sqrt_domain else m_new,
                              compression)
    return m_new


def init_opt_state(params, cfg: OptConfig):
    state = {
        "m": jax.tree.map(lambda p: _zeros_like_moment(p, cfg.state_compression),
                          params),
        "v": jax.tree.map(lambda p: _zeros_like_moment(p, cfg.state_compression),
                          params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, stats)."""
    count = opt_state["count"] + 1
    lr = schedule(cfg, opt_state["count"])
    b1, b2 = cfg.betas
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.clip_norm, cfg.clip_norm / (gnorm + 1e-12),
                      1.0)
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    src = opt_state.get("master", params)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        mf = _load_moment(m) * b1 + (1 - b1) * gf
        vf = _load_moment(v, sqrt_domain=True) * b2 + (1 - b2) * gf * gf
        mh, vh = mf / bc1, vf / bc2
        pf = p.astype(jnp.float32)
        # no weight decay on 1-D params (norms, biases), standard practice
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + wd * pf)
        return pf, mf, vf

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    flat_src = treedef.flatten_up_to(src)
    new_p, new_m, new_v, new_master = [], [], [], []
    for g, m, v, p, s in zip(flat_g, flat_m, flat_v, flat_p, flat_src):
        pf, mf, vf = upd(g, m, v, s)
        new_master.append(pf)
        new_p.append(pf.astype(p.dtype))
        new_m.append(_store_moment(mf, m, cfg.state_compression))
        new_v.append(_store_moment(vf, v, cfg.state_compression,
                                   sqrt_domain=True))
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "count": count}
    if cfg.master_fp32:
        new_state["master"] = jax.tree.unflatten(treedef, new_master)
    stats = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return new_params, new_state, stats


def opt_state_bytes(opt_state) -> int:
    """Actual bytes held by the optimizer state (compression accounting)."""
    return sum(t.size * t.dtype.itemsize
               for t in jax.tree.leaves(opt_state))

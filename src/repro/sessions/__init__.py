"""Multi-turn sessions, SLO scheduling, and trace-driven load
(DESIGN.md 15) -- the serving layer above the paged engine."""
from repro.sessions.loadgen import SessionTrace, Turn, make_trace
from repro.sessions.scheduler import (SLOScheduler, choose_resume,
                                      reprefill_cost_s, resume_cost_s)
from repro.sessions.session import Session, SessionManager
from repro.sessions.spec import (BATCH, INTERACTIVE, SessionSpec,
                                 SLOClass)

__all__ = [
    "BATCH", "INTERACTIVE", "SLOClass", "SessionSpec",
    "SessionTrace", "Turn", "make_trace",
    "SLOScheduler", "choose_resume", "resume_cost_s", "reprefill_cost_s",
    "Session", "SessionManager",
]

"""SLO-aware scheduling layered on the paged engine (DESIGN.md 15).

Two mechanisms, both operating on state the engine already exposes --
the scheduler never reaches into lane internals:

* PRIORITY ORDERING: the engine fills lanes from its ``parked`` deque
  FIFO; each tick the scheduler stable-sorts that deque by SLO-class
  priority, so an interactive turn passes queued batch work without a
  second queue structure.
* PREEMPT-BY-DEMOTION: when a high-priority request has sat laneless
  past the spec's patience, the scheduler demotes one lower-priority
  lane back to parked (``engine.preempt_lane``) -- at most one per
  tick, so the lane set never thrashes.

The module also holds the promotion-cost vs. re-prefill decision rule:
resuming a parked session costs its cold bytes over the host link plus
one decode step per unseen token, re-prefilling costs compute over the
FULL history -- replay wins exactly when the history's prefill FLOPs
outweigh the promotion traffic.
"""
from __future__ import annotations

import collections
from typing import Callable

from repro.assist.tasks import HOST_BW, PEAK_FLOPS
from repro.cache import TIER_COLD

from repro.sessions.spec import SessionSpec, SLOClass


def resume_cost_s(promote_bytes: float, n_active: float,
                  replay_len: int) -> float:
    """Seconds to resume by replay: cold pages over the host link, then
    one decode step (2*N FLOPs) per token the cache has not seen."""
    return (promote_bytes / HOST_BW
            + 2.0 * n_active * replay_len / PEAK_FLOPS)


def reprefill_cost_s(n_active: float, hist_len: int,
                     replay_len: int) -> float:
    """Seconds to resume by re-prefill: compute over history + turn."""
    return 2.0 * n_active * (hist_len + replay_len) / PEAK_FLOPS


def choose_resume(engine, rid: int, replay_len: int,
                  policy: str = "auto") -> str:
    """Pick "replay" or "reprefill" for a parked session's next turn.

    "auto" applies the cost rule against the session's ACTUAL cold
    footprint (pages still warm/hot promote for free, so a short gap
    biases toward replay even on a cold-heavy config)."""
    if policy != "auto":
        return policy
    hlen = engine.parked_session_len(rid)
    cold = [p for p in engine.session_pages(rid)
            if engine.store.tier[p] == TIER_COLD]
    promote_bytes = float(len(cold)) * engine.store.geom.warm_page_bytes
    n_active = float(engine.cfg.active_param_count())
    if resume_cost_s(promote_bytes, n_active, replay_len) \
            < reprefill_cost_s(n_active, hlen, replay_len):
        return "replay"
    return "reprefill"


class SLOScheduler:
    """Priority ordering + patience-gated preemption over engine lanes."""

    def __init__(self, engine, spec: SessionSpec, metrics=None):
        self.engine = engine
        self.spec = spec
        self.metrics = metrics if metrics is not None \
            else engine.obs.metrics
        self._c_preempt: dict = {}
        self._waiting_since: dict = {}        # rid -> first laneless tick

    def _preempt_counter(self, cls_name: str):
        c = self._c_preempt.get(cls_name)
        if c is None:
            c = self._c_preempt[cls_name] = self.metrics.counter(
                "scheduler_preemptions_total",
                "lanes demoted so a higher-priority turn can run",
                cls=cls_name)
        return c

    def tick(self, now: int, cls_of: Callable[[int], SLOClass]):
        """Run once per engine tick, after dispatch and before
        ``engine.step()``.  ``cls_of`` maps a resident rid to its SLO
        class (non-session rids should map to the lowest priority)."""
        eng = self.engine
        if len(eng.parked) > 1:
            eng.parked = collections.deque(
                sorted(eng.parked, key=lambda r: cls_of(r).priority))
        # patience bookkeeping: residents without a lane accrue wait
        in_lane = set(r for r in eng.lanes if r is not None)
        laneless = [r for r in eng.parked if r in eng.resident]
        for r in laneless:
            self._waiting_since.setdefault(r, now)
        for r in list(self._waiting_since):
            if r in in_lane or r not in eng.resident:
                del self._waiting_since[r]
        if not self.spec.preempt or not laneless:
            return
        over = [r for r in laneless
                if now - self._waiting_since[r]
                >= self.spec.preempt_wait_ticks]
        if not over:
            return
        over.sort(key=lambda r: (cls_of(r).priority,
                                 self._waiting_since[r]))
        top = over[0]
        victims = [r for r in in_lane
                   if cls_of(r).priority > cls_of(top).priority]
        if not victims:
            return
        # demote the victim with the most budget left (the turn that
        # loses the least finished work); ONE preemption per tick
        victim = max(victims, key=lambda r: eng.resident[r].remaining)
        if eng.preempt_lane(victim):
            self._preempt_counter(cls_of(top).name).inc()
            try:
                eng.parked.remove(top)
            except ValueError:
                pass
            else:
                eng.parked.appendleft(top)

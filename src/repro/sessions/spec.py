"""SessionSpec / SLO classes -- declarative session-serving knobs.

Configuration only, like :mod:`repro.assist.spec`: this module never
imports the cache/serving layers, so ``ServeConfig`` can nest a
``SessionSpec`` without cycles and the sessions runtime consumes it at
build time (DESIGN.md 15).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One latency class: a name, a dispatch priority (lower wins), and
    the turn-latency budget (ticks from turn-ready to last token) that
    defines goodput for the class."""
    name: str
    priority: int
    turn_budget_ticks: int

    def __post_init__(self):
        if self.turn_budget_ticks < 1:
            raise ValueError("turn_budget_ticks must be >= 1")


#: default classes: interactive turns want an answer within a couple of
#: dozen ticks; batch turns tolerate an order of magnitude more
INTERACTIVE = SLOClass("interactive", priority=0, turn_budget_ticks=24)
BATCH = SLOClass("batch", priority=1, turn_budget_ticks=160)


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """How multi-turn sessions park, resume, and get scheduled.

      park                keep a finished turn's pages as a parked
                          session (False reproduces the stateless
                          baseline: every turn re-prefills its history)
      park_to_cold        push a parked session's pages down the tier
                          ladder right at park time (one batched-mover
                          episode); False leaves demotion to LRU pressure
      predictive_promote  enqueue a parked session's cold pages on the
                          prefetch queue ``promote_horizon_ticks`` before
                          its next turn becomes ready (WaSP lifted from
                          pages to sessions)
      promote_horizon_ticks  how far ahead of turn-ready to prefetch
      preempt             let the scheduler demote a lower-priority lane
                          when a higher-priority turn has waited
                          ``preempt_wait_ticks`` without a lane
      preempt_wait_ticks  patience before preempting
      resume_policy       "replay" always teacher-forces the unseen
                          tokens through the decode step; "reprefill"
                          always drops the parked pages and re-prefills
                          the full history; "auto" picks per turn by the
                          promotion-cost vs. re-prefill rule
                          (DESIGN.md 15)
      classes             the SLO classes traffic is tagged with
    """
    park: bool = True
    park_to_cold: bool = True
    predictive_promote: bool = True
    promote_horizon_ticks: int = 3
    preempt: bool = True
    preempt_wait_ticks: int = 4
    resume_policy: str = "auto"
    classes: Tuple[SLOClass, ...] = (INTERACTIVE, BATCH)

    def __post_init__(self):
        if self.resume_policy not in ("auto", "replay", "reprefill"):
            raise ValueError(f"resume_policy must be auto|replay|reprefill, "
                             f"got {self.resume_policy!r}")
        if self.promote_horizon_ticks < 0:
            raise ValueError("promote_horizon_ticks must be >= 0")
        if self.preempt_wait_ticks < 1:
            raise ValueError("preempt_wait_ticks must be >= 1")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO class names: {names}")

    def cls(self, name: str) -> SLOClass:
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(f"unknown SLO class {name!r} "
                       f"(have {[c.name for c in self.classes]})")

"""Deterministic trace-driven load generator for multi-turn sessions.

Three stochastic structures, all seeded through ONE ``numpy`` generator
so a trace is reproducible bit-for-bit from ``(seed, params)``:

* arrivals: exponential inter-arrival gaps (a Poisson process in tick
  time) decide when each session's FIRST turn becomes ready;
* prefix sharing: each session's first turn opens with one of
  ``n_prefixes`` shared headers drawn Zipfian -- a few hot system
  prompts dominate, the tail is rare -- sized to full pages so the
  radix prefix store can actually share them;
* turn gaps: Pareto (heavy-tailed) think time between a turn's last
  token and the next turn's arrival, capped so a benchmark run
  terminates.

Turns are trimmed so the running history + the turn's decode budget
always fits ``max_len`` -- the generator never emits a structurally
inadmissible session.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Turn:
    """One user turn: think-time gap since the previous turn finished,
    the turn's new prompt tokens, and its decode budget."""
    gap_ticks: int
    tokens: Tuple[int, ...]
    max_new: int


@dataclasses.dataclass(frozen=True)
class SessionTrace:
    sid: int
    slo: str                      # SLO class name (spec.SessionSpec.cls)
    start_tick: int
    turns: Tuple[Turn, ...]

    def total_prompt_tokens(self) -> int:
        return sum(len(t.tokens) for t in self.turns)


def make_trace(*, n_sessions: int, seed: int, vocab_size: int,
               page_size: int = 16, max_len: Optional[int] = None,
               mean_turns: float = 3.0,
               turn_tokens: Tuple[int, int] = (6, 18),
               max_new: int = 6,
               n_prefixes: int = 4, zipf_a: float = 1.6,
               arrival_rate: float = 0.5,
               gap_mean: float = 6.0, gap_tail: float = 1.5,
               gap_cap: int = 40,
               interactive_frac: float = 0.5) -> list:
    """Build ``n_sessions`` deterministic session traces.

    ``arrival_rate`` is sessions per tick; ``gap_mean``/``gap_tail``
    parameterize the Pareto think time (tail < 2 has infinite variance
    -- genuinely heavy -- hence the ``gap_cap``).  Tokens avoid id 0 so
    a trace token can never collide with the pad id.
    """
    if n_sessions < 1:
        raise ValueError("n_sessions must be >= 1")
    rng = np.random.default_rng(seed)
    lo, hi = turn_tokens
    tok = lambda n: tuple(int(t) for t in
                          rng.integers(1, vocab_size, size=n))
    # shared headers: one full page each, so admission can share them
    headers = [tok(page_size) for _ in range(n_prefixes)]
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate,
                                         size=n_sessions))
    traces = []
    for sid in range(n_sessions):
        slo = ("interactive" if rng.random() < interactive_frac
               else "batch")
        n_turns = max(1, 1 + int(rng.poisson(max(mean_turns - 1.0, 0.0))))
        header = headers[min(int(rng.zipf(zipf_a)) - 1, n_prefixes - 1)]
        turns = []
        hist = 0
        for t in range(n_turns):
            body = tok(int(rng.integers(lo, hi + 1)))
            toks = header + body if t == 0 else body
            if max_len is not None and hist + len(toks) + max_new > max_len:
                break                      # history budget: trim the tail
            gap = 0 if t == 0 else \
                1 + int(min(rng.pareto(gap_tail) * gap_mean, gap_cap))
            turns.append(Turn(gap_ticks=gap, tokens=toks, max_new=max_new))
            hist += len(toks) + max_new
        if not turns:
            continue
        traces.append(SessionTrace(sid=sid, slo=slo,
                                   start_tick=int(arrivals[sid]),
                                   turns=tuple(turns)))
    if not traces:
        raise ValueError("max_len too small: every generated session "
                         "was trimmed to zero turns")
    traces.sort(key=lambda s: (s.start_tick, s.sid))
    return traces

"""Multi-turn sessions over the paged engine (DESIGN.md 15).

A ``Session`` owns one conversation from a load-generator trace.  Its
lifecycle is the state machine

    queued -> prefill/decoding -> parked -> resuming -> ... -> done

driven by :class:`SessionManager`, which advances the engine tick by
tick and, between a session's turns:

* PARKS the finished turn -- the engine keeps every page the request
  owns (token pages, MLA latents, state slab, shared-prefix refs) and
  ``park_session_pages`` pushes them down the tier ladder in one
  batched-mover episode;
* predictively RE-PROMOTES the parked pages shortly before the next
  turn becomes ready (``prefetch_session``, the WaSP idea lifted from
  pages to sessions), so promotion hides behind foreground decode;
* RESUMES without re-prefilling history: the unseen tokens (the new
  turn, plus at most one uncached tail token) teacher-force through the
  decode step against the cached pages.  The promotion-cost vs.
  re-prefill rule (scheduler.choose_resume) can fall back to a full
  re-prefill when the cold footprint outweighs the history compute.

Goodput is accounted per SLO class: a turn counts as GOOD only when its
last token lands within the class's tick budget of the turn becoming
ready -- tokens/s alone would credit late work.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.engine import Request
from repro.sessions.loadgen import SessionTrace
from repro.sessions.scheduler import SLOScheduler, choose_resume
from repro.sessions.spec import SessionSpec, SLOClass

# lifecycle states
QUEUED = "queued"          # first turn not yet submitted
DECODING = "decoding"      # a turn's request is in the engine
PARKED = "parked"          # between turns, pages kept (or dropped when
                           # the spec disables parking)
RESUMING = "resuming"      # a later turn's request is in the engine
DONE = "done"

#: tick-latency histogram buckets for session turns
TURN_LATENCY_BUCKETS = (2, 4, 8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass
class Session:
    """One conversation: trace position, accumulated token history, and
    per-turn accounting."""
    trace: SessionTrace
    slo: SLOClass
    state: str = QUEUED
    rid: Optional[int] = None
    turn_idx: int = 0
    history: list = dataclasses.field(default_factory=list)
    ready_tick: int = 0
    req: Optional[Request] = None
    parked_pages_kept: bool = False     # pages live across the gap?
    prefetched_gap: bool = False        # predictive promote fired?
    resumes_replay: int = 0
    resumes_reprefill: int = 0
    turn_latencies: list = dataclasses.field(default_factory=list)
    turns_ok: int = 0
    turns_violated: int = 0


class SessionManager:
    """Drive a set of session traces to completion over a PagedEngine."""

    def __init__(self, engine, spec: SessionSpec, traces, *, metrics=None):
        self.engine = engine
        self.spec = spec
        self.metrics = metrics if metrics is not None else engine.obs.metrics
        self.sessions = [Session(tr, spec.cls(tr.slo),
                                 ready_tick=tr.start_tick)
                         for tr in traces]
        self.scheduler = SLOScheduler(engine, spec, metrics=self.metrics)
        self._by_rid: dict = {}
        self.prefilled_prompt_tokens = 0    # tokens that went through prefill
        self.ticks = 0
        self._c_ok: dict = {}
        self._c_bad: dict = {}
        self._h_lat: dict = {}
        for c in spec.classes:
            self._c_ok[c.name] = self.metrics.counter(
                "session_turns_ok_total",
                "turns whose last token landed within the SLO budget",
                cls=c.name)
            self._c_bad[c.name] = self.metrics.counter(
                "session_slo_violations_total",
                "turns that missed their SLO budget", cls=c.name)
            self._h_lat[c.name] = self.metrics.histogram(
                "session_turn_latency_ticks",
                "ready-to-last-token latency per turn",
                TURN_LATENCY_BUCKETS, cls=c.name)

    # -- class lookup for the scheduler (non-session rids -> last) ----------

    def _cls_of(self, rid: int) -> SLOClass:
        s = self._by_rid.get(rid)
        if s is not None:
            return s.slo
        return min(self.spec.classes, key=lambda c: -c.priority)

    # -- turn completion ------------------------------------------------------

    def _harvest_turns(self, now: int):
        for s in self.sessions:
            if s.state not in (DECODING, RESUMING) or not s.req.done:
                continue
            if s.req.error is not None:
                # shed at admission or quarantined mid-decode (DESIGN.md
                # 17): the turn never completed -- count the violation
                # and end the session; its pages are already scrubbed
                s.turns_violated += 1
                self._c_bad[s.slo.name].inc()
                s.state = DONE
                self._by_rid.pop(s.rid, None)
                continue
            lat = now - s.ready_tick
            s.turn_latencies.append(lat)
            self._h_lat[s.slo.name].observe(lat)
            if lat <= s.slo.turn_budget_ticks:
                s.turns_ok += 1
                self._c_ok[s.slo.name].inc()
            else:
                s.turns_violated += 1
                self._c_bad[s.slo.name].inc()
            s.history.extend(s.req.out)
            s.turn_idx += 1
            gap = (s.trace.turns[s.turn_idx].gap_ticks
                   if s.turn_idx < len(s.trace.turns) else 0)
            if s.turn_idx >= len(s.trace.turns):
                # final turn retired WITHOUT park_on_retire: pages freed
                s.state = DONE
                self._by_rid.pop(s.rid, None)
                continue
            s.state = PARKED
            s.ready_tick = now + gap
            s.prefetched_gap = False
            s.parked_pages_kept = self.spec.park
            if self.spec.park and self.spec.park_to_cold:
                self.engine.park_session_pages(s.rid)

    # -- turn dispatch --------------------------------------------------------

    def _submit_turn(self, s: Session, turn, *, full_prompt: list):
        """Fresh-prefill path (first turn, or re-prefill resume)."""
        req = Request(rid=s.rid if s.rid is not None else s.trace.sid,
                      prompt=list(full_prompt), max_new=turn.max_new,
                      cls=s.slo.name)
        self.engine.submit(req)
        if s.rid is not None:
            self._by_rid.pop(s.rid, None)
        s.rid, s.req = req.rid, req            # submit may recycle the rid
        self._by_rid[req.rid] = s
        if (self.spec.park and not req.done   # done here = shed at intake
                and s.turn_idx + 1 < len(s.trace.turns)):
            self.engine.park_on_retire(req.rid)
        self.prefilled_prompt_tokens += len(full_prompt)

    def _dispatch_ready(self, now: int):
        order = sorted(
            (s for s in self.sessions
             if s.ready_tick <= now and s.state in (QUEUED, PARKED)),
            key=lambda s: (s.slo.priority, s.ready_tick))
        for s in order:
            turn = s.trace.turns[s.turn_idx]
            if s.state == QUEUED:
                self._submit_turn(s, turn, full_prompt=list(turn.tokens))
                s.history.extend(turn.tokens)
                s.state = DECODING
                continue
            # parked -> resuming
            if not s.parked_pages_kept:
                self._submit_turn(s, turn,
                                  full_prompt=s.history + list(turn.tokens))
                s.history.extend(turn.tokens)
                s.resumes_reprefill += 1
                s.state = RESUMING
                continue
            cached = self.engine.parked_session_len(s.rid)
            replay = s.history[cached:] + list(turn.tokens)
            mode = choose_resume(self.engine, s.rid, len(replay),
                                 policy=self.spec.resume_policy)
            if mode == "replay":
                req = Request(rid=s.rid,
                              prompt=s.history + list(turn.tokens),
                              max_new=turn.max_new, cls=s.slo.name)
                self.engine.resume_session(req, replay)
                s.req = req
                if s.turn_idx + 1 < len(s.trace.turns):
                    self.engine.park_on_retire(s.rid)
                s.resumes_replay += 1
            else:
                self.engine.release_session(s.rid)
                self._submit_turn(s, turn,
                                  full_prompt=s.history + list(turn.tokens))
                s.resumes_reprefill += 1
            s.history.extend(turn.tokens)
            s.state = RESUMING

    def _predictive_promote(self, now: int):
        if not (self.spec.park and self.spec.predictive_promote):
            return
        for s in self.sessions:
            if (s.state == PARKED and s.parked_pages_kept
                    and not s.prefetched_gap
                    and s.ready_tick - now <= self.spec.promote_horizon_ticks):
                self.engine.prefetch_session(s.rid)
                s.prefetched_gap = True

    # -- main loop ------------------------------------------------------------

    def done(self) -> bool:
        return all(s.state == DONE for s in self.sessions)

    def run(self, max_ticks: int = 20_000) -> dict:
        while not self.done() and self.ticks < max_ticks:
            now = self.engine.tick_no
            self._harvest_turns(now)
            self._predictive_promote(now)
            self._dispatch_ready(now)
            self.scheduler.tick(now, self._cls_of)
            self.engine.step()
            self.ticks += 1
        self._harvest_turns(self.engine.tick_no)   # turns landing last tick
        return self.report()

    # -- accounting -----------------------------------------------------------

    def report(self) -> dict:
        gv = self.metrics.get_value
        per_class = {}
        for c in self.spec.classes:
            sess = [s for s in self.sessions if s.slo.name == c.name]
            lats = sorted(l for s in sess for l in s.turn_latencies)
            ok = sum(s.turns_ok for s in sess)
            bad = sum(s.turns_violated for s in sess)
            pct = lambda q: (float(lats[min(int(q * len(lats)),
                                            len(lats) - 1)])
                             if lats else None)
            per_class[c.name] = {
                "sessions": len(sess),
                "turns": ok + bad,
                "turns_ok": ok,
                "slo_violations": bad,
                "budget_ticks": c.turn_budget_ticks,
                "goodput_frac": ok / (ok + bad) if ok + bad else None,
                "goodput_turns_per_ktick":
                    1000.0 * ok / max(self.ticks, 1),
                "p50_latency_ticks": pct(0.50),
                "p95_latency_ticks": pct(0.95),
            }
        return {
            "ticks": self.ticks,
            "sessions": len(self.sessions),
            "turns": sum(len(s.trace.turns) for s in self.sessions),
            "per_class": per_class,
            "resumes_replay": sum(s.resumes_replay for s in self.sessions),
            "resumes_reprefill": sum(s.resumes_reprefill
                                     for s in self.sessions),
            "replayed_tokens": gv("engine_replayed_tokens_total") or 0,
            "prefilled_prompt_tokens": self.prefilled_prompt_tokens,
            "session_parks": gv("engine_session_parks_total") or 0,
            "preemptions": gv("engine_preemptions_total") or 0,
            "tokens_generated": self.engine.tokens_generated,
        }
